//! Native *fused* online ABFT for Level-3 BLAS (paper §5.2, Fig. 4 right).
//!
//! The §5.1 unfused scheme (`abft::dgemm_abft_unfused`) pays separate
//! O(n²) memory passes per rank-k interval: encoding GEMVs over the A/B
//! panels and reference-checksum passes over all of C. On machines where
//! GEMM throughput dwarfs memory bandwidth that extra traffic costs ~15 %.
//! The paper's fix is to *fuse* every checksum access into loads the GEMM
//! already performs:
//!
//! - `C = β·C` scaling pass → also seeds the encoded and reference
//!   checksums (each C element is read exactly once anyway);
//! - packing `B` into `B̃` → also accumulates `B_panel·e` (row sums of the
//!   panel, the `B^c` of the paper) for this column block;
//! - packing `A` into `Ã` → also accumulates the encoded row checksum
//!   contribution `dC^r = α·A_panel·(B_panel·e)` and the panel column
//!   sums `e^T·A_panel`, whose product with the packed B̃ (cache-hot, about
//!   to be streamed by the macro kernel anyway) yields `dC^c`;
//! - the macro kernel's register-resident `acc` tile → reused at
//!   write-back to update the *reference* checksums `C^r_ref`, `C^c_ref`.
//!
//! After the fusion the FT overhead is purely computational — no memory
//! access happens that the unprotected GEMM would not also perform.
//!
//! Loop nest: unlike `blas::level3::dgemm` (j outermost), the rank-k loop
//! `p` is outermost so each `K_C` step is a verification interval — the
//! online error model corrects one error per interval (paper §2.1), so a
//! multi-error run is tolerated as long as strikes land in distinct
//! intervals.
//!
//! Injection model: `(step, i, j, delta)` perturbs the *computed tile
//! value* for global element (i, j) during rank-step `step`, before both
//! the store to C and the fused reference-checksum update — exactly where
//! a transient fault in the FMA pipeline would land. The corrupted value
//! therefore pollutes `C` and `C^r_ref`/`C^c_ref` coherently while the
//! encoded checksums (derived from A and B) still predict the true sums,
//! which is what makes detection possible.

use crate::blas::level3::GemmParams;
use crate::ft::abft::{self, LocatedError};
use crate::ft::FtReport;
use crate::util::arena;

/// One planned strike: (rank-k step, global row, global col, magnitude).
pub type Strike = (usize, usize, usize, f64);

/// Pack a (mcb × kcb) block of A into MR-row micro panels, fused with
/// checksum work (paper: "each element of A loaded for packing is reused
/// to update the column checksum"):
/// - `dcr[i]` += α · A[i][p] · be[p]  (encoded row-checksum contribution)
/// - `eta[p]` += A[i][p]              (panel column sums, for dC^c)
/// - running max|A| for the round-off threshold.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pack_a_fused(a: &[f64], lda: usize, i0: usize, p0: usize,
                           mcb: usize, kcb: usize, mr: usize, alpha: f64,
                           be: &[f64], out: &mut [f64], dcr: &mut [f64],
                           eta: &mut [f64]) {
    let mut w = 0;
    let mut i = 0;
    while i < mcb {
        let rows = mr.min(mcb - i);
        for p in 0..kcb {
            let bev = be[p];
            let mut col_sum = 0.0;
            for r in 0..rows {
                let v = a[(i0 + i + r) * lda + p0 + p];
                out[w] = v;
                w += 1;
                // fused checksum accumulation (block-local index) — same
                // loaded value
                dcr[i + r] += alpha * v * bev;
                col_sum += v;
            }
            eta[p] += col_sum;
            for _ in rows..mr {
                out[w] = 0.0;
                w += 1;
            }
        }
        i += mr;
    }
}

/// Pack a (kcb × ncb) block of B into NR-col micro panels, fused with the
/// panel row-sum accumulation `be[p] += Σ_j B[p][j]` (the paper's B^c
/// computed "simultaneously by reusing B") and the running max|B|.
pub(crate) fn pack_b_fused(b: &[f64], ldb: usize, p0: usize, j0: usize,
                           kcb: usize, ncb: usize, nr: usize, out: &mut [f64],
                           be: &mut [f64]) {
    let mut w = 0;
    let mut j = 0;
    while j < ncb {
        let cols = nr.min(ncb - j);
        for p in 0..kcb {
            let mut rsum = 0.0;
            for cdx in 0..cols {
                let v = b[(p0 + p) * ldb + j0 + j + cdx];
                out[w] = v;
                w += 1;
                rsum += v;
            }
            be[p] += rsum;
            for _ in cols..nr {
                out[w] = 0.0;
                w += 1;
            }
        }
        j += nr;
    }
}

/// MR×NR micro kernel — identical compute to `level3`'s, duplicated here
/// so the fused write-back can consume the register tile directly.
#[inline(always)]
fn micro_kernel(kc: usize, ap: &[f64], bp: &[f64], mr: usize, nr: usize,
                acc: &mut [f64]) {
    debug_assert_eq!(acc.len(), mr * nr);
    if mr == 4 && nr == 8 {
        // const-shape fast path: with MR/NR fixed the 4x8 accumulator
        // tile is fully register-allocated (4 zmm under AVX-512) and the
        // inner body is 4 broadcast-FMA rows per k step — the paper's
        // hand-picked micro-kernel parameters (§3.3.2)
        let tile: &mut [f64; 32] = (&mut acc[..32]).try_into().unwrap();
        micro_kernel_4x8(kc, ap, bp, tile);
        return;
    }
    for v in acc.iter_mut() {
        *v = 0.0;
    }
    for p in 0..kc {
        let arow = &ap[p * mr..(p + 1) * mr];
        let brow = &bp[p * nr..(p + 1) * nr];
        for r in 0..mr {
            let av = arow[r];
            let dst = &mut acc[r * nr..(r + 1) * nr];
            for (d, bv) in dst.iter_mut().zip(brow) {
                *d += av * bv;
            }
        }
    }
}

/// The 4x8 micro kernel with a compile-time-shaped accumulator tile.
#[inline(always)]
fn micro_kernel_4x8(kc: usize, ap: &[f64], bp: &[f64], acc: &mut [f64; 32]) {
    let mut tile = [0.0f64; 32];
    for p in 0..kc {
        let arow: &[f64; 4] = ap[p * 4..p * 4 + 4].try_into().unwrap();
        let brow: &[f64; 8] = bp[p * 8..p * 8 + 8].try_into().unwrap();
        for r in 0..4 {
            let av = arow[r];
            for l in 0..8 {
                tile[r * 8 + l] += av * brow[l];
            }
        }
    }
    *acc = tile;
}

/// Vectorized max|v| over a packed (cache-hot) buffer: 8 independent
/// per-lane max chains, folded once — keeps the round-off-threshold
/// bookkeeping out of the packing routines' inner loops, where a single
/// running-max accumulator would serialize them at fmax latency.
pub(crate) fn max_abs(v: &[f64]) -> f64 {
    let mut lanes = [0.0f64; 8];
    let mut chunks = v.chunks_exact(8);
    for c in &mut chunks {
        for (m, x) in lanes.iter_mut().zip(c) {
            *m = m.max(x.abs());
        }
    }
    let mut mx = lanes.iter().fold(0.0f64, |a, &b| a.max(b));
    for x in chunks.remainder() {
        mx = mx.max(x.abs());
    }
    mx
}

/// Pairwise (tree) sum of a tile row delta — three add levels instead of
/// a serial seven-add chain on the reference-checksum update path.
#[inline(always)]
fn row_sum(d: &[f64]) -> f64 {
    if d.len() == 8 {
        ((d[0] + d[1]) + (d[2] + d[3])) + ((d[4] + d[5]) + (d[6] + d[7]))
    } else {
        d.iter().sum()
    }
}

/// C := α·A·B + β·C with fused online ABFT (paper §5.2).
///
/// Corrects at most one error per rank-K_C verification interval; strikes
/// in `inject` landing in distinct steps are all corrected. Returns the
/// detected/corrected counts.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_abft_fused(m: usize, n: usize, k: usize, alpha: f64, a: &[f64],
                        b: &[f64], beta: f64, c: &mut [f64],
                        params: &GemmParams, inject: &[Strike]) -> FtReport {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return FtReport::none();
    }
    let &GemmParams { mc, nc, kc, mr, nr } = params;
    // every transient buffer — global checksum vectors, packing panels,
    // ABFT scratch — comes from the thread-local arena in one zeroed
    // lease, so steady-state protected GEMMs allocate nothing
    arena::with(
        [m, n, m, n,
         arena::packed_a_len(mc, kc, mr), arena::packed_b_len(nc, kc, nr),
         mr * nr, kc, kc, mc, mc, nc, nc],
        |[cr_enc, cc_enc, cr_ref, cc_ref, apack, bpack, acc, be, eta,
          crenc_loc, crref_loc, ccref_loc, ccenc_loc]| {
            fused_driver(m, n, k, alpha, a, b, beta, c, params, inject,
                         FusedScratch { cr_enc, cc_enc, cr_ref, cc_ref,
                                        apack, bpack, acc, be, eta,
                                        crenc_loc, crref_loc, ccref_loc,
                                        ccenc_loc })
        },
    )
}

/// Per-call scratch of one fused-ABFT GEMM, leased zero-filled from the
/// [`crate::util::arena`]: the global encoded/reference checksum
/// vectors, the packed A/B panels, the accumulator tile, the per-depth
/// block sums (`be`/`eta`), and the block-local checksum accumulators.
struct FusedScratch<'s> {
    cr_enc: &'s mut [f64],
    cc_enc: &'s mut [f64],
    cr_ref: &'s mut [f64],
    cc_ref: &'s mut [f64],
    apack: &'s mut [f64],
    bpack: &'s mut [f64],
    acc: &'s mut [f64],
    be: &'s mut [f64],
    eta: &'s mut [f64],
    crenc_loc: &'s mut [f64],
    crref_loc: &'s mut [f64],
    ccref_loc: &'s mut [f64],
    ccenc_loc: &'s mut [f64],
}

/// The fused loop nest, operating entirely on arena-leased scratch.
#[allow(clippy::too_many_arguments)]
fn fused_driver(m: usize, n: usize, k: usize, alpha: f64, a: &[f64],
                b: &[f64], beta: f64, c: &mut [f64], params: &GemmParams,
                inject: &[Strike], scratch: FusedScratch<'_>) -> FtReport {
    let FusedScratch { cr_enc, cc_enc, cr_ref, cc_ref, apack, bpack, acc,
                       be, eta, crenc_loc, crref_loc, ccref_loc,
                       ccenc_loc } = scratch;
    let &GemmParams { mc, nc, kc, mr, nr } = params;
    let mut report = FtReport::none();

    // ---- fused β-scaling + checksum seeding (paper: "the encoding of
    // C^c and C^r is fused with the matrix scaling routine C = βC")
    for i in 0..m {
        let row = &mut c[i * n..(i + 1) * n];
        let mut rsum = 0.0;
        for (j, v) in row.iter_mut().enumerate() {
            *v *= beta;
            rsum += *v;
            cc_enc[j] += *v;
        }
        cr_enc[i] = rsum;
    }
    // reference checksums start in agreement and are maintained at tile
    // write-back from the register acc values
    cr_ref.copy_from_slice(cr_enc);
    cc_ref.copy_from_slice(cc_enc);

    if k == 0 || alpha == 0.0 {
        return report;
    }

    // The block-local checksum accumulators (`*_loc`): the macro-kernel
    // write-back and the packing routines scatter read-modify-writes
    // across the full m/n-length checksum vectors otherwise, which
    // (depending on heap layout) can alias the streaming C rows in the
    // same cache sets — bimodal 20% swings across process runs. Compact
    // locals stay in L1 and are flushed once per block.
    let (mut max_a, mut max_b) = (0.0f64, 0.0f64);

    // Correcting an error of magnitude M cannot restore C below ~eps·|M|
    // accuracy (the large delta is absorbed into and subtracted from much
    // smaller sums), so each correction widens later intervals' round-off
    // threshold accordingly — otherwise the residual re-triggers forever.
    let mut corrected_tol = 0.0f64;

    // rank-k loop outermost: each K_C step is one verification interval
    let mut p0 = 0;
    let mut step = 0;
    while p0 < k {
        let kcb = kc.min(k - p0);
        let mut j0 = 0;
        while j0 < n {
            let ncb = nc.min(n - j0);
            be[..kcb].fill(0.0);
            pack_b_fused(b, n, p0, j0, kcb, ncb, nr, bpack,
                         &mut be[..kcb]);
            // threshold bookkeeping over the packed (cache-hot) buffer —
            // one vectorized pass, instead of a serialized running max in
            // the packing inner loop
            max_b = max_b.max(max_abs(&bpack[..ncb.div_ceil(nr) * nr * kcb]));
            let mut i0 = 0;
            while i0 < m {
                let mcb = mc.min(m - i0);
                eta[..kcb].fill(0.0);
                crenc_loc[..mcb].fill(0.0);
                crref_loc[..mcb].fill(0.0);
                ccenc_loc[..ncb].fill(0.0);
                ccref_loc[..ncb].fill(0.0);
                pack_a_fused(a, k, i0, p0, mcb, kcb, mr, alpha, &be[..kcb],
                             apack, crenc_loc, &mut eta[..kcb]);
                if j0 == 0 {
                    max_a = max_a.max(max_abs(
                        &apack[..mcb.div_ceil(mr) * mr * kcb]));
                }
                // dC^c contribution of this (i-block, j-block) pair:
                // (e^T A_block) · B̃ — B̃ is the packed, cache-hot buffer
                // the macro kernel is about to stream anyway
                {
                    let mut jj = 0;
                    while jj < ncb {
                        let cols = nr.min(ncb - jj);
                        let bp = &bpack[(jj / nr) * (nr * kcb)..][..nr * kcb];
                        for p in 0..kcb {
                            let ep = alpha * eta[p];
                            let brow = &bp[p * nr..p * nr + cols];
                            let dst = &mut ccenc_loc[jj..jj + cols];
                            for (d, bv) in dst.iter_mut().zip(brow) {
                                *d += ep * bv;
                            }
                        }
                        jj += nr;
                    }
                }
                // ---- macro kernel with fused reference-checksum update
                let mut jj = 0;
                while jj < ncb {
                    let nrb = nr.min(ncb - jj);
                    let bp = &bpack[(jj / nr) * (nr * kcb)..][..nr * kcb];
                    let mut ii = 0;
                    while ii < mcb {
                        let mrb = mr.min(mcb - ii);
                        let ap = &apack[(ii / mr) * (mr * kcb)..][..mr * kcb];
                        micro_kernel(kcb, ap, bp, mr, nr, acc);
                        // transient-fault injection: corrupt the computed
                        // register value before it is consumed anywhere
                        for &(s, fi, fj, delta) in inject {
                            if s == step
                                && fi >= i0 + ii && fi < i0 + ii + mrb
                                && fj >= j0 + jj && fj < j0 + jj + nrb
                            {
                                acc[(fi - i0 - ii) * nr + (fj - j0 - jj)] +=
                                    delta / alpha;
                            }
                        }
                        // write-back reusing the register tile for the
                        // reference checksums (paper: "we reuse the
                        // computed C elements at register level"). The
                        // delta row is staged in registers so the store,
                        // the column-checksum update, and the (pairwise)
                        // row-checksum sum are three independent
                        // vectorizable streams — no serial rsum chain.
                        for r in 0..mrb {
                            let gi = i0 + ii + r;
                            let crow = &mut c[gi * n + j0 + jj..][..nrb];
                            let arow = &acc[r * nr..r * nr + nrb];
                            let ccref = &mut ccref_loc[jj..jj + nrb];
                            let mut drow = [0.0f64; 16];
                            let drow = &mut drow[..nrb];
                            for (dv, av) in drow.iter_mut().zip(arow) {
                                *dv = alpha * av;
                            }
                            for (cv, dv) in crow.iter_mut().zip(drow.iter()) {
                                *cv += dv;
                            }
                            for (cc, dv) in ccref.iter_mut().zip(drow.iter()) {
                                *cc += dv;
                            }
                            crref_loc[ii + r] += row_sum(drow);
                        }
                        ii += mr;
                    }
                    jj += nr;
                }
                // flush the block-local checksum accumulators
                for (g, l) in cr_enc[i0..i0 + mcb].iter_mut()
                    .zip(&crenc_loc[..mcb])
                {
                    *g += l;
                }
                for (g, l) in cr_ref[i0..i0 + mcb].iter_mut()
                    .zip(&crref_loc[..mcb])
                {
                    *g += l;
                }
                for (g, l) in cc_enc[j0..j0 + ncb].iter_mut()
                    .zip(&ccenc_loc[..ncb])
                {
                    *g += l;
                }
                for (g, l) in cc_ref[j0..j0 + ncb].iter_mut()
                    .zip(&ccref_loc[..ncb])
                {
                    *g += l;
                }
                i0 += mc;
            }
            j0 += nc;
        }
        // ---- end of verification interval: O(m+n) compare / locate /
        // correct (the only non-fused work — negligible)
        let tol = abft::round_off_threshold(
            alpha.abs().max(1.0) * max_a * max_b, k, n.max(m)) + corrected_tol;
        if let Some(err) = verify_refs(cr_enc, cc_enc, cr_ref, cc_ref, tol) {
            c[err.i * n + err.j] -= err.magnitude;
            // bring the maintained reference sums back in line with the
            // corrected C so later intervals verify against truth
            cr_ref[err.i] -= err.magnitude;
            cc_ref[err.j] -= err.magnitude;
            corrected_tol += err.magnitude.abs() * f64::EPSILON * 64.0;
            report.errors_detected += 1;
            report.errors_corrected += 1;
        }
        p0 += kc;
        step += 1;
    }
    report
}

/// Compare maintained reference sums against encoded predictions; locate
/// a single error (row checksum first, column only on disagreement —
/// paper §5.1's short-circuit).
pub(crate) fn verify_refs(cr_enc: &[f64], cc_enc: &[f64], cr_ref: &[f64],
                          cc_ref: &[f64], tol: f64) -> Option<LocatedError> {
    let mut i_err = None;
    let mut worst = tol;
    for (i, (r, e)) in cr_ref.iter().zip(cr_enc).enumerate() {
        let d = (r - e).abs();
        if d > worst {
            worst = d;
            i_err = Some(i);
        }
    }
    let i = i_err?;
    let mut j_err = 0;
    let mut worst_c = 0.0;
    for (j, (r, e)) in cc_ref.iter().zip(cc_enc).enumerate() {
        let d = (r - e).abs();
        if d > worst_c {
            worst_c = d;
            j_err = j;
        }
    }
    Some(LocatedError { i, j: j_err, magnitude: cr_ref[i] - cr_enc[i] })
}

/// C := α·sym(A)·B + β·C with fused ABFT. The symmetrization is the
/// packing-routine modification of §6.2.3 — materialized once, then the
/// fused GEMM frame runs unchanged.
#[allow(clippy::too_many_arguments)]
pub fn dsymm_abft_fused(m: usize, n: usize, alpha: f64, a: &[f64], b: &[f64],
                        beta: f64, c: &mut [f64], params: &GemmParams,
                        inject: &[Strike]) -> FtReport {
    let mut full = vec![0.0; m * m];
    for i in 0..m {
        for j in 0..=i {
            let v = a[i * m + j];
            full[i * m + j] = v;
            full[j * m + i] = v;
        }
    }
    dgemm_abft_fused(m, n, m, alpha, &full, b, beta, c, params, inject)
}

/// B := α·tril(A)·B with fused ABFT (the §6.2.3 DTRMM kernel
/// modification: the packed A reads only the lower triangle).
pub fn dtrmm_abft_fused(m: usize, n: usize, alpha: f64, a: &[f64],
                        b: &mut [f64], params: &GemmParams,
                        inject: &[Strike]) -> FtReport {
    let mut low = vec![0.0; m * m];
    for i in 0..m {
        low[i * m..i * m + i + 1].copy_from_slice(&a[i * m..i * m + i + 1]);
    }
    let b0 = b.to_vec();
    b.fill(0.0);
    dgemm_abft_fused(m, n, m, alpha, &low, &b0, 0.0, b, params, inject)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure};
    use crate::util::matrix::{allclose, Matrix};

    fn small_params(g: &mut crate::util::check::Gen) -> GemmParams {
        GemmParams {
            mc: [8, 16, 32][g.rng.below(3)],
            nc: [8, 16, 32][g.rng.below(3)],
            kc: [4, 8, 16][g.rng.below(3)],
            mr: [2, 4][g.rng.below(2)],
            nr: [4, 8][g.rng.below(2)],
        }
    }

    #[test]
    fn fused_matches_naive_clean() {
        check("abft-fused-clean", 25, |g| {
            let m = g.dim(1, 48);
            let n = g.dim(1, 48);
            let k = g.dim(1, 48);
            let params = small_params(g);
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let (alpha, beta) = (g.rng.range(-2.0, 2.0), g.rng.range(-1.0, 1.0));
            let mut want = c0.data.clone();
            naive::dgemm(m, n, k, alpha, &a.data, &b.data, beta, &mut want);
            let mut c = c0.data.clone();
            let rep = dgemm_abft_fused(m, n, k, alpha, &a.data, &b.data, beta,
                                       &mut c, &params, &[]);
            ensure(rep == FtReport::none(),
                   format!("false positive on clean fused gemm: {rep:?}"))?;
            ensure(allclose(&c, &want, 1e-9, 1e-9), "fused gemm wrong value")
        });
    }

    #[test]
    fn fused_corrects_single_injection() {
        check("abft-fused-inject", 30, |g| {
            let m = g.dim(4, 48);
            let n = g.dim(4, 48);
            let k = g.dim(4, 64);
            let params = small_params(g);
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let alpha = g.rng.range(0.5, 2.0);
            let beta = g.rng.range(-1.0, 1.0);
            let mut want = c0.data.clone();
            naive::dgemm(m, n, k, alpha, &a.data, &b.data, beta, &mut want);
            let steps = k.div_ceil(params.kc);
            let strike = (g.rng.below(steps), g.rng.below(m), g.rng.below(n),
                          g.rng.range(1.0, 1e5));
            let mut c = c0.data.clone();
            let rep = dgemm_abft_fused(m, n, k, alpha, &a.data, &b.data, beta,
                                       &mut c, &params, &[strike]);
            ensure(rep.errors_detected == 1 && rep.errors_corrected == 1,
                   format!("report {rep:?} for strike {strike:?}"))?;
            ensure(allclose(&c, &want, 1e-8, 1e-8),
                   "fused abft did not restore C")
        });
    }

    #[test]
    fn fused_corrects_one_error_per_interval() {
        check("abft-fused-multi", 15, |g| {
            let m = g.dim(8, 40);
            let n = g.dim(8, 40);
            let k = g.dim(32, 96);
            let params = GemmParams { kc: 8, ..small_params(g) };
            let steps = k.div_ceil(params.kc);
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let mut want = vec![0.0; m * n];
            naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
            // one strike in every other interval — all distinct steps
            let strikes: Vec<Strike> = (0..steps)
                .step_by(2)
                .map(|s| (s, g.rng.below(m), g.rng.below(n),
                          g.rng.range(10.0, 1e4)))
                .collect();
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_fused(m, n, k, 1.0, &a.data, &b.data, 0.0,
                                       &mut c, &params, &strikes);
            ensure(rep.errors_corrected == strikes.len() as u64,
                   format!("{rep:?}, wanted {} corrections", strikes.len()))?;
            ensure(allclose(&c, &want, 1e-8, 1e-8),
                   "multi-interval correction failed")
        });
    }

    #[test]
    fn fused_and_unfused_agree_under_injection() {
        check("abft-fused-vs-unfused", 15, |g| {
            let m = g.dim(8, 32);
            let n = g.dim(8, 32);
            let k = g.dim(16, 48);
            let params = GemmParams { kc: 8, ..Default::default() };
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let steps = k.div_ceil(params.kc);
            let strike = (g.rng.below(steps), g.rng.below(m), g.rng.below(n),
                          g.rng.range(1.0, 1e4));
            let mut c_f = vec![0.0; m * n];
            let rep_f = dgemm_abft_fused(m, n, k, 1.0, &a.data, &b.data, 0.0,
                                         &mut c_f, &params, &[strike]);
            let mut c_u = vec![0.0; m * n];
            let rep_u = abft::dgemm_abft_unfused(
                m, n, k, params.kc, &a.data, &b.data, &mut c_u,
                |ap, bp, cc, mm, kk| {
                    naive::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, cc);
                },
                Some(strike),
            );
            ensure(rep_f == rep_u, format!("fused {rep_f:?} unfused {rep_u:?}"))?;
            ensure(allclose(&c_f, &c_u, 1e-8, 1e-8),
                   "fused and unfused results diverge")
        });
    }

    #[test]
    fn dsymm_fused_clean_and_injected() {
        check("abft-fused-symm", 15, |g| {
            let m = g.dim(4, 40);
            let n = g.dim(4, 40);
            let params = small_params(g);
            let a = Matrix::random(m, m, &mut g.rng);
            let b = Matrix::random(m, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let mut want = c0.data.clone();
            naive::dsymm_lower(m, n, 1.2, &a.data, &b.data, 0.3, &mut want);
            let mut c = c0.data.clone();
            let rep = dsymm_abft_fused(m, n, 1.2, &a.data, &b.data, 0.3,
                                       &mut c, &params, &[]);
            ensure(rep == FtReport::none(), "symm clean flagged")?;
            ensure(allclose(&c, &want, 1e-9, 1e-9), "symm clean value")?;
            let steps = m.div_ceil(params.kc);
            let strike = (g.rng.below(steps), g.rng.below(m), g.rng.below(n),
                          5e4);
            let mut c = c0.data.clone();
            let rep = dsymm_abft_fused(m, n, 1.2, &a.data, &b.data, 0.3,
                                       &mut c, &params, &[strike]);
            ensure(rep.errors_corrected == 1, format!("symm inject {rep:?}"))?;
            ensure(allclose(&c, &want, 1e-8, 1e-8), "symm not corrected")
        });
    }

    #[test]
    fn dtrmm_fused_clean_and_injected() {
        check("abft-fused-trmm", 15, |g| {
            let m = g.dim(4, 40);
            let n = g.dim(4, 40);
            let params = small_params(g);
            let a = Matrix::random(m, m, &mut g.rng);
            let b0 = Matrix::random(m, n, &mut g.rng);
            let mut want = b0.data.clone();
            naive::dtrmm_lower(m, n, 0.9, &a.data, &mut want);
            let mut b = b0.data.clone();
            let rep = dtrmm_abft_fused(m, n, 0.9, &a.data, &mut b, &params, &[]);
            ensure(rep == FtReport::none(), "trmm clean flagged")?;
            ensure(allclose(&b, &want, 1e-9, 1e-9), "trmm clean value")?;
            let steps = m.div_ceil(params.kc);
            let strike = (g.rng.below(steps), g.rng.below(m), g.rng.below(n),
                          -3e4);
            let mut b = b0.data.clone();
            let rep = dtrmm_abft_fused(m, n, 0.9, &a.data, &mut b, &params,
                                       &[strike]);
            ensure(rep.errors_corrected == 1, format!("trmm inject {rep:?}"))?;
            ensure(allclose(&b, &want, 1e-8, 1e-8), "trmm not corrected")
        });
    }

    #[test]
    fn degenerate_shapes() {
        let params = GemmParams::default();
        let mut c: Vec<f64> = vec![];
        let rep = dgemm_abft_fused(0, 0, 4, 1.0, &[], &[], 1.0, &mut c,
                                   &params, &[]);
        assert_eq!(rep, FtReport::none());
        // k = 0: pure beta scaling, checksums still consistent
        let mut c = vec![1.0, 2.0, 3.0, 4.0];
        let rep = dgemm_abft_fused(2, 2, 0, 1.0, &[], &[], 0.5, &mut c,
                                   &params, &[]);
        assert_eq!(rep, FtReport::none());
        assert_eq!(c, vec![0.5, 1.0, 1.5, 2.0]);
    }
}
