//! FT policy: which protection scheme the coordinator applies to a
//! request. The paper's hybrid strategy (§1): DMR for memory-bound
//! Level-1/2, fused online ABFT for compute-bound Level-3.
//!
//! A policy names the protection the *caller* wants; which kernel
//! implements it for a given routine is resolved by the kernel registry
//! ([`crate::coordinator::registry`]) via each descriptor's capability
//! list.

use crate::coordinator::registry::KernelRegistry;
use crate::ft::injector::CampaignTarget;

/// Protection scheme selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FtPolicy {
    /// No protection (the "Ori" baseline and all reference libraries).
    None,
    /// The paper's hybrid: DMR for L1/L2 routines, fused ABFT for L3.
    /// This is "FT-BLAS: FT".
    Hybrid,
    /// Unfused ABFT built on top of an unprotected backend (the paper's
    /// §5.1 "ABFT on a third-party library" — Fig. 8's slow baseline).
    /// Applies to L3 routines only; L1/L2 fall back to DMR.
    AbftUnfused,
    /// Weighted (double) checksum ABFT — the Chen & Dongarra encoding
    /// the paper's §2.1 cites, fused into the GEMM frame
    /// (`ft::abft_weighted`). Applies to DGEMM; other L3 routines fall
    /// back to the §5.2 fused scheme and L1/L2 to DMR.
    AbftWeighted,
}

impl FtPolicy {
    /// Every policy, in CLI/report order.
    pub const ALL: [FtPolicy; 4] = [
        FtPolicy::None,
        FtPolicy::Hybrid,
        FtPolicy::AbftUnfused,
        FtPolicy::AbftWeighted,
    ];

    /// CLI/report name of the policy.
    pub fn name(&self) -> &'static str {
        match self {
            FtPolicy::None => "none",
            FtPolicy::Hybrid => "hybrid",
            FtPolicy::AbftUnfused => "abft-unfused",
            FtPolicy::AbftWeighted => "abft-weighted",
        }
    }

    /// Parse a policy name (the CLI's `--ft`, with aliases).
    pub fn by_name(s: &str) -> Option<FtPolicy> {
        match s {
            "none" | "off" => Some(FtPolicy::None),
            "hybrid" | "on" | "ft" => Some(FtPolicy::Hybrid),
            "abft-unfused" | "unfused" => Some(FtPolicy::AbftUnfused),
            "abft-weighted" | "weighted" => Some(FtPolicy::AbftWeighted),
            _ => None,
        }
    }

    /// Whether the policy applies any protection at all.
    pub fn protects(&self) -> bool {
        !matches!(self, FtPolicy::None)
    }

    /// Whether an injection campaign with this `target` can ever strike
    /// while the tier serves under this policy — i.e. whether any
    /// registered kernel that serves the policy runs a scheme the
    /// target admits. `ftblas soak` validates its flags through this,
    /// so a run that would inject nothing (e.g. `--target fused` under
    /// a DMR-only policy, or anything under `none`) fails fast instead
    /// of "passing" vacuously.
    pub fn reaches(&self, target: CampaignTarget) -> bool {
        KernelRegistry::global()
            .entries()
            .iter()
            .any(|e| e.supports(*self) && target.admits(e.scheme))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for p in FtPolicy::ALL {
            assert_eq!(FtPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(FtPolicy::by_name("on"), Some(FtPolicy::Hybrid));
        assert_eq!(FtPolicy::by_name("weighted"), Some(FtPolicy::AbftWeighted));
        assert!(FtPolicy::by_name("bogus").is_none());
    }

    #[test]
    fn all_protect_except_none() {
        for p in FtPolicy::ALL {
            assert_eq!(p.protects(), p != FtPolicy::None);
        }
    }

    /// Campaign reachability mirrors the registry's capability lists:
    /// `none` reaches nothing, the hybrid policy reaches every target
    /// set, and the unfused policy cannot reach the fused kernels.
    #[test]
    fn campaign_reachability_follows_the_registry() {
        for t in CampaignTarget::ALL {
            assert!(!FtPolicy::None.reaches(t),
                    "unprotected serving reaches no campaign target");
            assert!(FtPolicy::Hybrid.reaches(CampaignTarget::AllProtected));
        }
        assert!(FtPolicy::Hybrid.reaches(CampaignTarget::Dmr));
        assert!(FtPolicy::Hybrid.reaches(CampaignTarget::Fused));
        assert!(FtPolicy::AbftUnfused.reaches(CampaignTarget::Abft));
        assert!(!FtPolicy::AbftUnfused.reaches(CampaignTarget::Fused),
                "the unfused policy never plans a fused kernel");
    }
}
