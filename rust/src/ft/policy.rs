//! FT policy: which protection scheme the coordinator applies to a
//! request. The paper's hybrid strategy (§1): DMR for memory-bound
//! Level-1/2, fused online ABFT for compute-bound Level-3.

/// Protection scheme selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FtPolicy {
    /// No protection (the "Ori" baseline and all reference libraries).
    None,
    /// The paper's hybrid: DMR for L1/L2 routines, fused ABFT for L3.
    /// This is "FT-BLAS: FT".
    Hybrid,
    /// Unfused ABFT built on top of an unprotected backend (the paper's
    /// §5.1 "ABFT on a third-party library" — Fig. 8's slow baseline).
    /// Applies to L3 routines only; L1/L2 fall back to DMR.
    AbftUnfused,
}

impl FtPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            FtPolicy::None => "none",
            FtPolicy::Hybrid => "hybrid",
            FtPolicy::AbftUnfused => "abft-unfused",
        }
    }

    pub fn by_name(s: &str) -> Option<FtPolicy> {
        match s {
            "none" | "off" => Some(FtPolicy::None),
            "hybrid" | "on" | "ft" => Some(FtPolicy::Hybrid),
            "abft-unfused" | "unfused" => Some(FtPolicy::AbftUnfused),
            _ => None,
        }
    }

    pub fn protects(&self) -> bool {
        !matches!(self, FtPolicy::None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for p in [FtPolicy::None, FtPolicy::Hybrid, FtPolicy::AbftUnfused] {
            assert_eq!(FtPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(FtPolicy::by_name("on"), Some(FtPolicy::Hybrid));
        assert!(FtPolicy::by_name("bogus").is_none());
    }
}
