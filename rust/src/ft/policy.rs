//! FT policy: which protection scheme the coordinator applies to a
//! request. The paper's hybrid strategy (§1): DMR for memory-bound
//! Level-1/2, fused online ABFT for compute-bound Level-3.
//!
//! A policy names the protection the *caller* wants; which kernel
//! implements it for a given routine is resolved by the kernel registry
//! ([`crate::coordinator::registry`]) via each descriptor's capability
//! list.

/// Protection scheme selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FtPolicy {
    /// No protection (the "Ori" baseline and all reference libraries).
    None,
    /// The paper's hybrid: DMR for L1/L2 routines, fused ABFT for L3.
    /// This is "FT-BLAS: FT".
    Hybrid,
    /// Unfused ABFT built on top of an unprotected backend (the paper's
    /// §5.1 "ABFT on a third-party library" — Fig. 8's slow baseline).
    /// Applies to L3 routines only; L1/L2 fall back to DMR.
    AbftUnfused,
    /// Weighted (double) checksum ABFT — the Chen & Dongarra encoding
    /// the paper's §2.1 cites, fused into the GEMM frame
    /// (`ft::abft_weighted`). Applies to DGEMM; other L3 routines fall
    /// back to the §5.2 fused scheme and L1/L2 to DMR.
    AbftWeighted,
}

impl FtPolicy {
    /// Every policy, in CLI/report order.
    pub const ALL: [FtPolicy; 4] = [
        FtPolicy::None,
        FtPolicy::Hybrid,
        FtPolicy::AbftUnfused,
        FtPolicy::AbftWeighted,
    ];

    /// CLI/report name of the policy.
    pub fn name(&self) -> &'static str {
        match self {
            FtPolicy::None => "none",
            FtPolicy::Hybrid => "hybrid",
            FtPolicy::AbftUnfused => "abft-unfused",
            FtPolicy::AbftWeighted => "abft-weighted",
        }
    }

    /// Parse a policy name (the CLI's `--ft`, with aliases).
    pub fn by_name(s: &str) -> Option<FtPolicy> {
        match s {
            "none" | "off" => Some(FtPolicy::None),
            "hybrid" | "on" | "ft" => Some(FtPolicy::Hybrid),
            "abft-unfused" | "unfused" => Some(FtPolicy::AbftUnfused),
            "abft-weighted" | "weighted" => Some(FtPolicy::AbftWeighted),
            _ => None,
        }
    }

    /// Whether the policy applies any protection at all.
    pub fn protects(&self) -> bool {
        !matches!(self, FtPolicy::None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for p in FtPolicy::ALL {
            assert_eq!(FtPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(FtPolicy::by_name("on"), Some(FtPolicy::Hybrid));
        assert_eq!(FtPolicy::by_name("weighted"), Some(FtPolicy::AbftWeighted));
        assert!(FtPolicy::by_name("bogus").is_none());
    }

    #[test]
    fn all_protect_except_none() {
        for p in FtPolicy::ALL {
            assert_eq!(p.protects(), p != FtPolicy::None);
        }
    }
}
