//! DMR protection for the *native* Level-1/2 routines (paper §4).
//!
//! Sphere of replication: computing instructions only — operands are
//! loaded once, both compute streams read the same loaded values, the
//! duplicate stream's constants are laundered through `black_box` so the
//! optimizer cannot collapse the two streams (the Rust analog of really
//! issuing the duplicated vmulpd). Verification is chunk-wise with
//! comparison reduction; recovery recomputes the disagreeing lanes and
//! re-verifies (the paper's third computation + consensus check).
//!
//! The fully-laddered DSCAL lives in `blas::stepwise` (Fig. 7); this
//! module applies the final-step scheme (pipelined + reduced comparisons)
//! to the rest of the L1/L2 routines.
//!
//! Injection: `Option<(usize, f64)>` — perturb the primary stream's
//! element/partial at the given output index by delta, exactly once.

use std::hint::black_box;

use crate::blas::level1::LANES;
use crate::blas::level2::RI;
use crate::ft::FtReport;

#[cold]
#[inline(never)]
fn unrecoverable() -> ! {
    panic!("FT-BLAS DMR: streams disagree after recomputation — unrecoverable");
}

/// DSCAL with DMR — the top rung of the Fig. 7 ladder.
pub fn dscal_ft(alpha: f64, x: &mut [f64], inject: Option<(usize, f64)>) -> FtReport {
    let errs = crate::blas::stepwise::v5_prefetch_ft(alpha, x, inject) as u64;
    FtReport { errors_detected: errs, errors_corrected: errs }
}

/// DAXPY with DMR: chunked duplicate FMA streams.
pub fn daxpy_ft(alpha: f64, x: &[f64], y: &mut [f64],
                inject: Option<(usize, f64)>) -> FtReport {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let a2 = black_box(alpha);
    let mut errs = 0u64;
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let mut prim = [0.0f64; LANES];
        let mut orig = [0.0f64; LANES];
        let mut mask = 0u32;
        for l in 0..LANES {
            orig[l] = y[i + l];
            prim[l] = alpha * x[i + l] + orig[l];
        }
        if let Some((idx, d)) = inject {
            if idx >= i && idx < i + LANES {
                prim[idx - i] += d;
            }
        }
        // duplicate FMA stream: a2 is the once-laundered alpha, so both
        // streams vectorize but cannot be CSE'd into one
        let mut dup = [0.0f64; LANES];
        for l in 0..LANES {
            dup[l] = a2 * x[i + l] + orig[l];
        }
        for l in 0..LANES {
            mask |= ((prim[l] != dup[l]) as u32) << l;
            y[i + l] = prim[l];
        }
        if mask != 0 {
            errs += mask.count_ones() as u64;
            for l in 0..LANES {
                if mask & (1 << l) != 0 {
                    let r1 = black_box(alpha) * black_box(x[i + l]) + orig[l];
                    let r2 = black_box(alpha) * black_box(x[i + l]) + orig[l];
                    if r1 != r2 {
                        unrecoverable();
                    }
                    y[i + l] = r1;
                }
            }
        }
        i += LANES;
    }
    for l in main..n {
        let orig = y[l];
        let mut prim = alpha * x[l] + orig;
        if let Some((idx, d)) = inject {
            if idx == l {
                prim += d;
            }
        }
        let dup = a2 * x[l] + orig;
        if prim != dup {
            errs += 1;
            prim = dup;
        }
        y[l] = prim;
    }
    FtReport { errors_detected: errs, errors_corrected: errs }
}

/// DDOT with DMR: two fully duplicated accumulator-chain sets, verified
/// bitwise at the horizontal-reduce point (the paper's verification
/// interval for reductions). The clean path carries no per-chunk
/// compare/branch — just the duplicated FMA chains, which hide entirely
/// under the two input streams' memory traffic. On a mismatch the cold
/// path recomputes a third chain and takes the dup/third consensus.
/// Injection: `(chunk, delta)` perturbs the primary chain's partial.
pub fn ddot_ft(x: &[f64], y: &[f64], inject: Option<(usize, f64)>)
               -> (f64, FtReport) {
    assert_eq!(x.len(), y.len());
    let one = black_box(1.0); // laundered multiplier for the dup stream
    // primary + duplicate per-lane accumulator chains (identical op
    // order, so clean runs agree bitwise)
    let mut a1 = [0.0f64; LANES];
    let mut a2 = [0.0f64; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        for l in 0..LANES {
            a1[l] += xs[l] * ys[l];
            a2[l] += (one * xs[l]) * ys[l];
        }
    }
    let mut t1 = 0.0f64;
    let mut t2 = 0.0f64;
    for (xi, yi) in xc.remainder().iter().zip(yc.remainder()) {
        t1 += xi * yi;
        t2 += (one * xi) * yi;
    }
    if let Some((_, d)) = inject {
        // the strike lands on the primary chain's running partial; it is
        // carried to the verification point like any transient ALU flip
        a1[0] += d;
    }
    let mut diff = 0u64;
    for l in 0..LANES {
        diff |= a1[l].to_bits() ^ a2[l].to_bits();
    }
    diff |= t1.to_bits() ^ t2.to_bits();
    if diff == 0 {
        return (a1.iter().sum::<f64>() + t1, FtReport::none());
    }
    // cold: third chain + consensus with the duplicate
    let (a3, t3) = ddot_third(x, y);
    if a3 != a2 || t3 != t2 {
        unrecoverable();
    }
    (a3.iter().sum::<f64>() + t3,
     FtReport { errors_detected: 1, errors_corrected: 1 })
}

/// Third computation for the DDOT consensus (cold path).
#[cold]
#[inline(never)]
fn ddot_third(x: &[f64], y: &[f64]) -> ([f64; LANES], f64) {
    let lau = black_box(1.0);
    let mut acc = [0.0f64; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        for l in 0..LANES {
            acc[l] += (lau * xs[l]) * ys[l];
        }
    }
    let mut tail = 0.0f64;
    for (xi, yi) in xc.remainder().iter().zip(yc.remainder()) {
        tail += (lau * xi) * yi;
    }
    (acc, tail)
}

/// DNRM2 with DMR (duplicated sum-of-squares chains).
pub fn dnrm2_ft(x: &[f64], inject: Option<(usize, f64)>) -> (f64, FtReport) {
    let (ssq, rep) = ddot_ft(x, x, inject);
    if ssq.is_finite() && ssq > f64::MIN_POSITIVE {
        (ssq.sqrt(), rep)
    } else {
        (crate::blas::naive::dnrm2(x), rep)
    }
}

/// DGEMV with DMR: the per-row accumulations are duplicated; comparison
/// is per RI-row group (the paper's verification interval over the
/// register-blocked i-loop). Injection: output row index.
pub fn dgemv_ft(m: usize, n: usize, alpha: f64, a: &[f64], x: &[f64],
                beta: f64, y: &mut [f64], inject: Option<(usize, f64)>)
                -> FtReport {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    let mut errs = 0u64;
    let alpha2 = black_box(alpha);
    let one = black_box(1.0); // laundered multiplier for the dup streams
    let mi = m - m % RI;
    let nj = n - n % LANES;
    let mut i = 0;
    while i < mi {
        // primary + duplicate accumulator tiles: RI rows x LANES lanes,
        // both streams vectorize (the paper's vr_0..3 plus their shadows)
        let mut t1 = [[0.0f64; LANES]; RI];
        let mut t2 = [[0.0f64; LANES]; RI];
        let mut j = 0;
        while j < nj {
            for r in 0..RI {
                let row = &a[(i + r) * n + j..(i + r) * n + j + LANES];
                let xs = &x[j..j + LANES];
                for l in 0..LANES {
                    let xv2 = one * xs[l];
                    t1[r][l] += row[l] * xs[l];
                    t2[r][l] += row[l] * xv2;
                }
            }
            j += LANES;
        }
        let mut acc1 = [0.0f64; RI];
        let mut acc2 = [0.0f64; RI];
        for r in 0..RI {
            acc1[r] = t1[r].iter().sum();
            acc2[r] = t2[r].iter().sum();
            // identical op order in both tails keeps streams comparable
            for jj in nj..n {
                let av = a[(i + r) * n + jj];
                acc1[r] += av * x[jj];
                acc2[r] += av * (one * x[jj]);
            }
        }
        if let Some((idx, d)) = inject {
            if idx >= i && idx < i + RI {
                acc1[idx - i] += d;
            }
        }
        let mut mask = 0u32;
        for r in 0..RI {
            mask |= ((acc1[r] != acc2[r]) as u32) << r;
        }
        if mask != 0 {
            errs += mask.count_ones() as u64;
            for r in 0..RI {
                if mask & (1 << r) != 0 {
                    // recompute the corrupted row (third stream), with the
                    // same tile summation order so consensus is bitwise
                    let mut t3 = [0.0f64; LANES];
                    let mut j = 0;
                    while j < nj {
                        let row = &a[(i + r) * n + j..(i + r) * n + j + LANES];
                        for l in 0..LANES {
                            t3[l] += black_box(row[l]) * x[j + l];
                        }
                        j += LANES;
                    }
                    let mut p3: f64 = t3.iter().sum();
                    for jj in nj..n {
                        p3 += black_box(a[(i + r) * n + jj]) * x[jj];
                    }
                    if p3 != acc2[r] {
                        unrecoverable();
                    }
                    acc1[r] = p3;
                }
            }
        }
        for r in 0..RI {
            y[i + r] = alpha * acc1[r] + beta * y[i + r];
        }
        i += RI;
    }
    while i < m {
        let row = &a[i * n..(i + 1) * n];
        let mut p1 = 0.0;
        let mut p2 = 0.0;
        for j in 0..n {
            p1 += row[j] * x[j];
            p2 += row[j] * (one * x[j]);
        }
        if let Some((idx, d)) = inject {
            if idx == i {
                p1 += d;
            }
        }
        if p1 != p2 {
            errs += 1;
            p1 = p2;
        }
        y[i] = alpha * p1 + beta * y[i];
        i += 1;
    }
    // verify alpha stream too (cheap scalar check)
    if alpha != alpha2 {
        unrecoverable();
    }
    FtReport { errors_detected: errs, errors_corrected: errs }
}

/// DTRSV with DMR: panel updates through `dgemv_ft`, diagonal forward
/// substitution duplicated and verified (paper's scheme for the Level-1
/// diagonal section). Injection: (panel step, delta) perturbs that
/// step's gemv partial at its first row.
pub fn dtrsv_ft(n: usize, a: &[f64], x: &mut [f64], panel: usize,
                inject: Option<(usize, f64)>) -> FtReport {
    assert_eq!(a.len(), n * n);
    assert_eq!(x.len(), n);
    let mut report = FtReport::none();
    let mut i = 0;
    let mut step = 0;
    while i < n {
        let b = panel.min(n - i);
        if i > 0 {
            let mut panel_rows = vec![0.0; b * i];
            for r in 0..b {
                panel_rows[r * i..(r + 1) * i]
                    .copy_from_slice(&a[(i + r) * n..(i + r) * n + i]);
            }
            let mut upd = vec![0.0; b];
            let inj = inject.and_then(|(s, d)| (s == step).then_some((0usize, d)));
            report.merge(dgemv_ft(b, i, 1.0, &panel_rows, &x[..i], 0.0,
                                  &mut upd, inj));
            for r in 0..b {
                x[i + r] -= upd[r];
            }
        }
        // diagonal block: duplicated forward substitution
        let solve = |x: &[f64], out: &mut [f64]| {
            for r in 0..b {
                let row = &a[(i + r) * n + i..(i + r) * n + i + r];
                let mut acc = x[i + r];
                for (j, &v) in row.iter().enumerate() {
                    acc -= v * out[j];
                }
                out[r] = acc / a[(i + r) * n + i + r];
            }
        };
        let mut s1 = vec![0.0; b];
        let mut s2 = vec![0.0; b];
        solve(x, &mut s1);
        solve(x, &mut s2);
        if s1 != s2 {
            report.errors_detected += 1;
            let mut s3 = vec![0.0; b];
            solve(x, &mut s3);
            if s3 != s2 {
                unrecoverable();
            }
            s1 = s3;
            report.errors_corrected += 1;
        }
        x[i..i + b].copy_from_slice(&s1);
        i += b;
        step += 1;
    }
    report
}

/// DASUM with DMR: duplicated |x| accumulation chains, verified bitwise
/// at the horizontal-reduce point (same scheme as [`ddot_ft`]).
pub fn dasum_ft(x: &[f64], inject: Option<(usize, f64)>) -> (f64, FtReport) {
    let one = black_box(1.0);
    let mut a1 = [0.0f64; LANES];
    let mut a2 = [0.0f64; LANES];
    let mut xc = x.chunks_exact(LANES);
    for xs in &mut xc {
        for l in 0..LANES {
            a1[l] += xs[l].abs();
            a2[l] += (one * xs[l]).abs();
        }
    }
    let mut t1 = 0.0f64;
    let mut t2 = 0.0f64;
    for v in xc.remainder() {
        t1 += v.abs();
        t2 += (one * v).abs();
    }
    if let Some((_, d)) = inject {
        a1[0] += d;
    }
    let mut diff = 0u64;
    for l in 0..LANES {
        diff |= a1[l].to_bits() ^ a2[l].to_bits();
    }
    diff |= t1.to_bits() ^ t2.to_bits();
    if diff == 0 {
        return (a1.iter().sum::<f64>() + t1, FtReport::none());
    }
    // cold: third chain + consensus with the duplicate
    let (a3, t3) = dasum_third(x);
    if a3 != a2 || t3 != t2 {
        unrecoverable();
    }
    (a3.iter().sum::<f64>() + t3,
     FtReport { errors_detected: 1, errors_corrected: 1 })
}

/// Third computation for the DASUM consensus (cold path).
#[cold]
#[inline(never)]
fn dasum_third(x: &[f64]) -> ([f64; LANES], f64) {
    let lau = black_box(1.0);
    let mut acc = [0.0f64; LANES];
    let mut xc = x.chunks_exact(LANES);
    for xs in &mut xc {
        for l in 0..LANES {
            acc[l] += (lau * xs[l]).abs();
        }
    }
    let mut tail = 0.0f64;
    for v in xc.remainder() {
        tail += (lau * v).abs();
    }
    (acc, tail)
}

/// DROT with DMR: both rotation streams computed from the same loaded
/// (x, y) pair; per-chunk comparison reduction. Injection: element index
/// perturbs the primary x-stream.
pub fn drot_ft(x: &mut [f64], y: &mut [f64], c: f64, s: f64,
               inject: Option<(usize, f64)>) -> FtReport {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let (c2, s2) = (black_box(c), black_box(s));
    let mut errs = 0u64;
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let mut px = [0.0f64; LANES];
        let mut py = [0.0f64; LANES];
        let mut dx = [0.0f64; LANES];
        let mut dy = [0.0f64; LANES];
        for l in 0..LANES {
            let (xa, yb) = (x[i + l], y[i + l]);
            px[l] = c * xa + s * yb;
            py[l] = c * yb - s * xa;
            dx[l] = c2 * xa + s2 * yb;
            dy[l] = c2 * yb - s2 * xa;
        }
        if let Some((idx, d)) = inject {
            if idx >= i && idx < i + LANES {
                px[idx - i] += d;
            }
        }
        let mut diff = 0u64;
        for l in 0..LANES {
            diff |= (px[l].to_bits() ^ dx[l].to_bits())
                | (py[l].to_bits() ^ dy[l].to_bits());
        }
        if diff != 0 {
            errs += 1;
            // third computation + consensus, then in-register restore
            for l in 0..LANES {
                let (xa, yb) = (x[i + l], y[i + l]);
                let tx = black_box(c) * xa + black_box(s) * yb;
                let ty = black_box(c) * yb - black_box(s) * xa;
                if (px[l] != dx[l] && tx != dx[l])
                    || (py[l] != dy[l] && ty != dy[l])
                {
                    unrecoverable();
                }
                px[l] = tx;
                py[l] = ty;
            }
        }
        for l in 0..LANES {
            x[i + l] = px[l];
            y[i + l] = py[l];
        }
        i += LANES;
    }
    for l in main..n {
        let (xa, yb) = (x[l], y[l]);
        let (mut p, mut q) = (c * xa + s * yb, c * yb - s * xa);
        let (p2, q2) = (c2 * xa + s2 * yb, c2 * yb - s2 * xa);
        if p != p2 || q != q2 {
            errs += 1;
            p = p2;
            q = q2;
        }
        x[l] = p;
        y[l] = q;
    }
    FtReport { errors_detected: errs, errors_corrected: errs }
}

/// DROTM with DMR. The flag dispatch happens once; the duplicated
/// streams use laundered H entries.
pub fn drotm_ft(x: &mut [f64], y: &mut [f64], param: &[f64; 5],
                inject: Option<(usize, f64)>) -> FtReport {
    assert_eq!(x.len(), y.len());
    let flag = param[0];
    let (h11, h21, h12, h22) = match flag {
        f if f == -2.0 => return FtReport::none(),
        f if f == -1.0 => (param[1], param[2], param[3], param[4]),
        f if f == 0.0 => (1.0, param[2], param[3], 1.0),
        _ => (param[1], -1.0, 1.0, param[4]),
    };
    let (g11, g21, g12, g22) = (black_box(h11), black_box(h21),
                                black_box(h12), black_box(h22));
    let n = x.len();
    let mut errs = 0u64;
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let mut px = [0.0f64; LANES];
        let mut py = [0.0f64; LANES];
        let mut dx = [0.0f64; LANES];
        let mut dy = [0.0f64; LANES];
        for l in 0..LANES {
            let (xa, yb) = (x[i + l], y[i + l]);
            px[l] = h11 * xa + h12 * yb;
            py[l] = h21 * xa + h22 * yb;
            dx[l] = g11 * xa + g12 * yb;
            dy[l] = g21 * xa + g22 * yb;
        }
        if let Some((idx, d)) = inject {
            if idx >= i && idx < i + LANES {
                py[idx - i] += d;
            }
        }
        let mut diff = 0u64;
        for l in 0..LANES {
            diff |= (px[l].to_bits() ^ dx[l].to_bits())
                | (py[l].to_bits() ^ dy[l].to_bits());
        }
        if diff != 0 {
            errs += 1;
            for l in 0..LANES {
                let (xa, yb) = (x[i + l], y[i + l]);
                let tx = black_box(h11) * xa + black_box(h12) * yb;
                let ty = black_box(h21) * xa + black_box(h22) * yb;
                if (px[l] != dx[l] && tx != dx[l])
                    || (py[l] != dy[l] && ty != dy[l])
                {
                    unrecoverable();
                }
                px[l] = tx;
                py[l] = ty;
            }
        }
        for l in 0..LANES {
            x[i + l] = px[l];
            y[i + l] = py[l];
        }
        i += LANES;
    }
    for l in main..n {
        let (xa, yb) = (x[l], y[l]);
        let (mut p, mut q) = (h11 * xa + h12 * yb, h21 * xa + h22 * yb);
        let (p2, q2) = (g11 * xa + g12 * yb, g21 * xa + g22 * yb);
        if p != p2 || q != q2 {
            errs += 1;
            p = p2;
            q = q2;
        }
        x[l] = p;
        y[l] = q;
    }
    FtReport { errors_detected: errs, errors_corrected: errs }
}

/// IDAMAX with DMR: the comparison instructions *are* the compute here,
/// so the scan itself is duplicated; the two winners must agree.
/// Injection: (chunk, _) forces the primary stream to a wrong candidate
/// within that chunk.
pub fn idamax_ft(x: &[f64], inject: Option<(usize, f64)>) -> (usize, FtReport) {
    let n = x.len();
    if n == 0 {
        return (0, FtReport::none());
    }
    let scan = |corrupt: Option<usize>| -> usize {
        let mut best = 0usize;
        let mut bv = 0.0f64;
        let mut i = 0;
        let mut chunk = 0usize;
        while i < n {
            let end = (i + LANES).min(n);
            for l in i..end {
                let v = black_box(x[l]).abs();
                if v > bv {
                    bv = v;
                    best = l;
                }
            }
            if corrupt == Some(chunk) {
                // a flipped comparison result: the faulty stream adopts
                // this chunk's last element as the running winner
                best = end - 1;
                bv = x[end - 1].abs() + 1.0;
            }
            i = end;
            chunk += 1;
        }
        best
    };
    let p = scan(inject.map(|(c, _)| c % n.div_ceil(LANES)));
    let d = scan(None);
    if p == d {
        return (p, FtReport::none());
    }
    // third scan + consensus
    let t = scan(None);
    if t != d {
        unrecoverable();
    }
    (t, FtReport { errors_detected: 1, errors_corrected: 1 })
}

/// DGER with DMR: A += alpha x yᵀ with duplicated FMA streams per row
/// chunk. Injection: flat element index into A.
pub fn dger_ft(m: usize, n: usize, alpha: f64, x: &[f64], y: &[f64],
               a: &mut [f64], inject: Option<(usize, f64)>) -> FtReport {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);
    let one = black_box(1.0);
    let mut errs = 0u64;
    for i in 0..m {
        let axi = alpha * x[i];
        let axi2 = (one * alpha) * x[i];
        let row = &mut a[i * n..(i + 1) * n];
        let main = n - n % LANES;
        let mut j = 0;
        while j < main {
            let mut prim = [0.0f64; LANES];
            let mut dup = [0.0f64; LANES];
            let mut orig = [0.0f64; LANES];
            for l in 0..LANES {
                orig[l] = row[j + l];
                prim[l] = axi * y[j + l] + orig[l];
                dup[l] = axi2 * y[j + l] + orig[l];
            }
            if let Some((idx, d)) = inject {
                if idx >= i * n + j && idx < i * n + j + LANES {
                    prim[idx - i * n - j] += d;
                }
            }
            let mut mask = 0u32;
            for l in 0..LANES {
                mask |= ((prim[l] != dup[l]) as u32) << l;
            }
            if mask != 0 {
                errs += mask.count_ones() as u64;
                for l in 0..LANES {
                    if mask & (1 << l) != 0 {
                        let r1 = black_box(axi) * black_box(y[j + l]) + orig[l];
                        let r2 = black_box(axi) * black_box(y[j + l]) + orig[l];
                        if r1 != r2 {
                            unrecoverable();
                        }
                        prim[l] = r1;
                    }
                }
            }
            for l in 0..LANES {
                row[j + l] = prim[l];
            }
            j += LANES;
        }
        for l in main..n {
            let orig = row[l];
            let mut p = axi * y[l] + orig;
            if let Some((idx, d)) = inject {
                if idx == i * n + l {
                    p += d;
                }
            }
            let q = axi2 * y[l] + orig;
            if p != q {
                errs += 1;
                p = q;
            }
            row[l] = p;
        }
    }
    FtReport { errors_detected: errs, errors_corrected: errs }
}

/// DSYMV with DMR: per-row duplicated accumulation over the symmetric
/// read pattern (tril stored). Injection: output row index.
pub fn dsymv_ft(n: usize, alpha: f64, a: &[f64], x: &[f64], beta: f64,
                y: &mut [f64], inject: Option<(usize, f64)>) -> FtReport {
    assert_eq!(a.len(), n * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), n);
    let one = black_box(1.0);
    let mut errs = 0u64;
    for i in 0..n {
        let mut p1 = 0.0f64;
        let mut p2 = 0.0f64;
        for j in 0..n {
            let aij = if j <= i { a[i * n + j] } else { a[j * n + i] };
            p1 += aij * x[j];
            p2 += aij * (one * x[j]);
        }
        if let Some((idx, d)) = inject {
            if idx == i {
                p1 += d;
            }
        }
        if p1 != p2 {
            errs += 1;
            let mut p3 = 0.0f64;
            for j in 0..n {
                let aij = if j <= i { a[i * n + j] } else { a[j * n + i] };
                p3 += black_box(aij) * x[j];
            }
            if p3 != p2 {
                unrecoverable();
            }
            p1 = p3;
        }
        y[i] = alpha * p1 + beta * y[i];
    }
    FtReport { errors_detected: errs, errors_corrected: errs }
}

/// DTRMV with DMR: x := tril(A)·x, rows walked bottom-up with duplicated
/// accumulator chains. Injection: output row index.
pub fn dtrmv_ft(n: usize, a: &[f64], x: &mut [f64],
                inject: Option<(usize, f64)>) -> FtReport {
    assert_eq!(a.len(), n * n);
    assert_eq!(x.len(), n);
    let one = black_box(1.0);
    let mut errs = 0u64;
    for i in (0..n).rev() {
        let row = &a[i * n..i * n + i + 1];
        let mut p1 = 0.0f64;
        let mut p2 = 0.0f64;
        for (j, &aij) in row.iter().enumerate() {
            p1 += aij * x[j];
            p2 += aij * (one * x[j]);
        }
        if let Some((idx, d)) = inject {
            if idx == i {
                p1 += d;
            }
        }
        if p1 != p2 {
            errs += 1;
            let mut p3 = 0.0f64;
            for (j, &aij) in row.iter().enumerate() {
                p3 += black_box(aij) * x[j];
            }
            if p3 != p2 {
                unrecoverable();
            }
            p1 = p3;
        }
        x[i] = p1;
    }
    FtReport { errors_detected: errs, errors_corrected: errs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure, ensure_close};
    use crate::util::matrix::{allclose, Matrix};

    #[test]
    fn dscal_ft_clean_and_injected() {
        check("dmr-dscal", 25, |g| {
            let n = g.dim(1, 400);
            let alpha = g.rng.range(0.5, 2.0);
            let x0: Vec<f64> = (0..n).map(|_| g.rng.range(0.5, 2.0)).collect();
            let want: Vec<f64> = x0.iter().map(|v| alpha * v).collect();
            let mut x = x0.clone();
            let rep = dscal_ft(alpha, &mut x, None);
            ensure(rep.errors_detected == 0 && x == want, "clean run")?;
            let idx = g.rng.below(n);
            let mut x = x0.clone();
            let rep = dscal_ft(alpha, &mut x, Some((idx, 3.5)));
            ensure(rep.errors_detected == 1 && rep.errors_corrected == 1,
                   format!("inject rep {rep:?}"))?;
            ensure(x == want, "injected value not corrected")
        });
    }

    #[test]
    fn daxpy_ft_clean_and_injected() {
        check("dmr-daxpy", 25, |g| {
            let n = g.dim(1, 300);
            let alpha = g.rng.range(-2.0, 2.0);
            let x = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(n);
            let mut want = y0.clone();
            naive::daxpy(alpha, &x, &mut want);
            let mut y = y0.clone();
            let rep = daxpy_ft(alpha, &x, &mut y, None);
            ensure(rep.errors_detected == 0 && y == want, "clean daxpy")?;
            let idx = g.rng.below(n);
            let mut y = y0.clone();
            let rep = daxpy_ft(alpha, &x, &mut y, Some((idx, 9.0)));
            ensure(rep.errors_corrected == 1 && y == want, "injected daxpy")
        });
    }

    #[test]
    fn ddot_ft_clean_and_injected() {
        check("dmr-ddot", 25, |g| {
            let n = g.dim(8, 500);
            let x = g.rng.normal_vec(n);
            let y = g.rng.normal_vec(n);
            let want = naive::ddot(&x, &y);
            let (d, rep) = ddot_ft(&x, &y, None);
            ensure(rep.errors_detected == 0, "clean ddot flagged")?;
            ensure_close(d, want, 1e-12, "clean ddot value")?;
            let chunk = g.rng.below(n / 8);
            let (d, rep) = ddot_ft(&x, &y, Some((chunk, 1e3)));
            ensure(rep.errors_corrected == 1, "injected ddot not corrected")?;
            ensure_close(d, want, 1e-12, "injected ddot value")
        });
    }

    #[test]
    fn dgemv_ft_clean_and_injected() {
        check("dmr-dgemv", 20, |g| {
            let m = g.dim(1, 60);
            let n = g.dim(1, 60);
            let a = Matrix::random(m, n, &mut g.rng);
            let x = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(m);
            let mut want = y0.clone();
            naive::dgemv(m, n, 1.3, &a.data, &x, 0.4, &mut want);
            let mut y = y0.clone();
            let rep = dgemv_ft(m, n, 1.3, &a.data, &x, 0.4, &mut y, None);
            ensure(rep.errors_detected == 0, "clean gemv flagged")?;
            ensure(allclose(&y, &want, 1e-11, 1e-11), "clean gemv value")?;
            let idx = g.rng.below(m);
            let mut y = y0.clone();
            let rep = dgemv_ft(m, n, 1.3, &a.data, &x, 0.4, &mut y,
                               Some((idx, 2e4)));
            ensure(rep.errors_corrected == 1, format!("gemv inject {rep:?}"))?;
            ensure(allclose(&y, &want, 1e-11, 1e-11), "gemv not corrected")
        });
    }

    #[test]
    fn dtrsv_ft_clean_and_injected() {
        check("dmr-dtrsv", 20, |g| {
            let n = g.dim(8, 120);
            let a = Matrix::random_lower_triangular(n, &mut g.rng);
            let b = g.rng.normal_vec(n);
            let mut want = b.clone();
            naive::dtrsv_lower(n, &a.data, &mut want);
            let mut x = b.clone();
            let rep = dtrsv_ft(n, &a.data, &mut x, 4, None);
            ensure(rep.errors_detected == 0, "clean trsv flagged")?;
            ensure(allclose(&x, &want, 1e-9, 1e-9), "clean trsv value")?;
            let steps = n.div_ceil(4);
            let step = 1 + g.rng.below((steps - 1).max(1));
            let mut x = b.clone();
            let rep = dtrsv_ft(n, &a.data, &mut x, 4, Some((step, 5e3)));
            ensure(rep.errors_corrected >= 1, format!("trsv inject {rep:?}"))?;
            ensure(allclose(&x, &want, 1e-9, 1e-9), "trsv not corrected")
        });
    }

    #[test]
    fn dnrm2_ft_matches() {
        let mut rng = crate::util::rng::Rng::new(3);
        let x = rng.normal_vec(333);
        let (v, rep) = dnrm2_ft(&x, None);
        assert_eq!(rep.errors_detected, 0);
        assert!((v - naive::dnrm2(&x)).abs() < 1e-10);
    }

    #[test]
    fn dasum_ft_clean_and_injected() {
        check("dmr-dasum", 25, |g| {
            let n = g.dim(8, 500);
            let x = g.rng.normal_vec(n);
            let want = naive::dasum(&x);
            let (v, rep) = dasum_ft(&x, None);
            ensure(rep.errors_detected == 0, "clean dasum flagged")?;
            ensure_close(v, want, 1e-12, "clean dasum value")?;
            let chunk = g.rng.below(n / 8);
            let (v, rep) = dasum_ft(&x, Some((chunk, 7.0)));
            ensure(rep.errors_corrected == 1, "injected dasum not corrected")?;
            ensure_close(v, want, 1e-12, "injected dasum value")
        });
    }

    #[test]
    fn drot_ft_clean_and_injected() {
        check("dmr-drot", 25, |g| {
            let n = g.dim(1, 300);
            let (c, s) = (0.6, 0.8);
            let x0 = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(n);
            let (mut wx, mut wy) = (x0.clone(), y0.clone());
            naive::drot(&mut wx, &mut wy, c, s);
            let (mut x, mut y) = (x0.clone(), y0.clone());
            let rep = drot_ft(&mut x, &mut y, c, s, None);
            ensure(rep.errors_detected == 0 && x == wx && y == wy,
                   "clean drot")?;
            let idx = g.rng.below(n);
            let (mut x, mut y) = (x0, y0);
            let rep = drot_ft(&mut x, &mut y, c, s, Some((idx, 4.0)));
            // tail injections (idx >= main) are not applied — only
            // require correction when the strike landed in a chunk
            if idx < n - n % crate::blas::level1::LANES {
                ensure(rep.errors_corrected == 1,
                       format!("drot inject {rep:?}"))?;
            }
            ensure(x == wx && y == wy, "drot not corrected")
        });
    }

    #[test]
    fn drotm_ft_all_flags() {
        check("dmr-drotm", 30, |g| {
            let n = g.dim(1, 200);
            let flag = [-2.0, -1.0, 0.0, 1.0][g.rng.below(4)];
            let param = [flag, g.rng.range(-2.0, 2.0), g.rng.range(-2.0, 2.0),
                         g.rng.range(-2.0, 2.0), g.rng.range(-2.0, 2.0)];
            let x0 = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(n);
            let (mut wx, mut wy) = (x0.clone(), y0.clone());
            naive::drotm(&mut wx, &mut wy, &param);
            let (mut x, mut y) = (x0.clone(), y0.clone());
            let rep = drotm_ft(&mut x, &mut y, &param, None);
            ensure(rep.errors_detected == 0 && x == wx && y == wy,
                   format!("clean drotm flag {flag}"))?;
            if flag != -2.0 {
                let idx = g.rng.below(n);
                let (mut x, mut y) = (x0, y0);
                let rep = drotm_ft(&mut x, &mut y, &param, Some((idx, -3.0)));
                if idx < n - n % crate::blas::level1::LANES {
                    ensure(rep.errors_corrected == 1,
                           format!("drotm inject {rep:?}"))?;
                }
                ensure(x == wx && y == wy, "drotm not corrected")?;
            }
            Ok(())
        });
    }

    #[test]
    fn idamax_ft_clean_and_injected() {
        check("dmr-idamax", 30, |g| {
            let n = g.dim(1, 400);
            let x = g.rng.normal_vec(n);
            let want = naive::idamax(&x);
            let (i, rep) = idamax_ft(&x, None);
            ensure(rep.errors_detected == 0 && i == want, "clean idamax")?;
            let chunk = g.rng.below(n.div_ceil(8));
            let (i, rep) = idamax_ft(&x, Some((chunk, 0.0)));
            ensure(i == want, "idamax index not recovered")?;
            // the corrupted scan may coincidentally agree when the strike
            // lands on the true winner's chunk-end — only require
            // detection when the answers differed
            if rep.errors_detected > 0 {
                ensure(rep.errors_corrected == 1, format!("idamax {rep:?}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn dger_ft_clean_and_injected() {
        check("dmr-dger", 20, |g| {
            let m = g.dim(1, 50);
            let n = g.dim(1, 50);
            let alpha = g.rng.range(-2.0, 2.0);
            let x = g.rng.normal_vec(m);
            let y = g.rng.normal_vec(n);
            let a0 = Matrix::random(m, n, &mut g.rng);
            let mut want = a0.data.clone();
            naive::dger(m, n, alpha, &x, &y, &mut want);
            let mut a = a0.data.clone();
            let rep = dger_ft(m, n, alpha, &x, &y, &mut a, None);
            ensure(rep.errors_detected == 0 && a == want, "clean dger")?;
            let idx = g.rng.below(m * n);
            let mut a = a0.data.clone();
            let rep = dger_ft(m, n, alpha, &x, &y, &mut a, Some((idx, 11.0)));
            ensure(rep.errors_corrected == 1 && a == want,
                   format!("dger inject {rep:?}"))
        });
    }

    #[test]
    fn dsymv_ft_clean_and_injected() {
        check("dmr-dsymv", 20, |g| {
            let n = g.dim(1, 60);
            let a = Matrix::random(n, n, &mut g.rng);
            let x = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(n);
            let mut want = y0.clone();
            naive::dsymv_lower(n, 1.1, &a.data, &x, 0.7, &mut want);
            let mut y = y0.clone();
            let rep = dsymv_ft(n, 1.1, &a.data, &x, 0.7, &mut y, None);
            ensure(rep.errors_detected == 0, "clean dsymv flagged")?;
            ensure(allclose(&y, &want, 1e-11, 1e-11), "clean dsymv value")?;
            let idx = g.rng.below(n);
            let mut y = y0;
            let rep = dsymv_ft(n, 1.1, &a.data, &x, 0.7, &mut y,
                               Some((idx, 6e3)));
            ensure(rep.errors_corrected == 1, format!("dsymv inject {rep:?}"))?;
            ensure(allclose(&y, &want, 1e-11, 1e-11), "dsymv not corrected")
        });
    }

    #[test]
    fn dtrmv_ft_clean_and_injected() {
        check("dmr-dtrmv", 20, |g| {
            let n = g.dim(1, 80);
            let a = Matrix::random(n, n, &mut g.rng);
            let x0 = g.rng.normal_vec(n);
            let mut want = x0.clone();
            naive::dtrmv_lower(n, &a.data, &mut want);
            let mut x = x0.clone();
            let rep = dtrmv_ft(n, &a.data, &mut x, None);
            ensure(rep.errors_detected == 0, "clean dtrmv flagged")?;
            ensure(allclose(&x, &want, 1e-12, 1e-12), "clean dtrmv value")?;
            let idx = g.rng.below(n);
            let mut x = x0;
            let rep = dtrmv_ft(n, &a.data, &mut x, Some((idx, -8e2)));
            ensure(rep.errors_corrected == 1, format!("dtrmv inject {rep:?}"))?;
            ensure(allclose(&x, &want, 1e-12, 1e-12), "dtrmv not corrected")
        });
    }
}
