//! Weighted (double) checksum ABFT — the alternative single-error
//! location scheme the paper's §2.1 cites (Chen & Dongarra's online
//! double-checksum encoding): instead of a row checksum *and* a column
//! checksum, encode **two column-space checksums**,
//!
//! ```text
//!   s1 = eᵀ·C          (plain column sums,   e = [1, 1, …, 1])
//!   s2 = wᵀ·C          (weighted column sums, w = [1, 2, …, m])
//! ```
//!
//! A single error of magnitude δ at (i, j) shifts `s1[j]` by δ and
//! `s2[j]` by (i+1)·δ, so the column comes from the s1 scan and the row
//! decodes as `round(Δs2/Δs1) − 1` — no row-side checksums at all. The
//! trade: one extra weighted encoding stream (`wᵀA` next to `eᵀA` in the
//! packing), against dropping the `A·(B·e)` row-checksum stream; the
//! ablation bench (A4) measures the difference against the §5.2
//! row+column scheme.
//!
//! Restricted to C := A·B (α=1, β=0) — the shape the ablation and the
//! error-model tests exercise; the general frame lives in `abft_fused`.

use crate::blas::level3::GemmParams;
use crate::ft::abft_fused::Strike;
use crate::ft::FtReport;

/// Pack an (mcb × kcb) block of A into MR-row micro panels, fused with
/// the two column-sum streams: `eta1[p] += A[gi][p]` and
/// `eta2[p] += (gi+1)·A[gi][p]` (gi = global row).
#[allow(clippy::too_many_arguments)]
fn pack_a_weighted(a: &[f64], lda: usize, i0: usize, p0: usize, mcb: usize,
                   kcb: usize, mr: usize, out: &mut [f64],
                   eta1: &mut [f64], eta2: &mut [f64]) {
    let mut w = 0;
    let mut i = 0;
    while i < mcb {
        let rows = mr.min(mcb - i);
        for p in 0..kcb {
            let mut s1 = 0.0;
            let mut s2 = 0.0;
            for r in 0..rows {
                let gi = i0 + i + r;
                let v = a[gi * lda + p0 + p];
                out[w] = v;
                w += 1;
                s1 += v;
                s2 += (gi + 1) as f64 * v;
            }
            eta1[p] += s1;
            eta2[p] += s2;
            for _ in rows..mr {
                out[w] = 0.0;
                w += 1;
            }
        }
        i += mr;
    }
}

fn pack_b_plain(b: &[f64], ldb: usize, p0: usize, j0: usize, kcb: usize,
                ncb: usize, nr: usize, out: &mut [f64]) {
    let mut w = 0;
    let mut j = 0;
    while j < ncb {
        let cols = nr.min(ncb - j);
        for p in 0..kcb {
            for cdx in 0..cols {
                out[w] = b[(p0 + p) * ldb + j0 + j + cdx];
                w += 1;
            }
            for _ in cols..nr {
                out[w] = 0.0;
                w += 1;
            }
        }
        j += nr;
    }
}

#[inline(always)]
fn micro_kernel_4x8(kc: usize, ap: &[f64], bp: &[f64], acc: &mut [f64; 32]) {
    let mut tile = [0.0f64; 32];
    for p in 0..kc {
        let arow: &[f64; 4] = ap[p * 4..p * 4 + 4].try_into().unwrap();
        let brow: &[f64; 8] = bp[p * 8..p * 8 + 8].try_into().unwrap();
        for r in 0..4 {
            let av = arow[r];
            for l in 0..8 {
                tile[r * 8 + l] += av * brow[l];
            }
        }
    }
    *acc = tile;
}

#[inline(always)]
fn micro_kernel(kc: usize, ap: &[f64], bp: &[f64], mr: usize, nr: usize,
                acc: &mut [f64]) {
    if mr == 4 && nr == 8 {
        let tile: &mut [f64; 32] = (&mut acc[..32]).try_into().unwrap();
        micro_kernel_4x8(kc, ap, bp, tile);
        return;
    }
    for v in acc.iter_mut() {
        *v = 0.0;
    }
    for p in 0..kc {
        let arow = &ap[p * mr..(p + 1) * mr];
        let brow = &bp[p * nr..(p + 1) * nr];
        for r in 0..mr {
            let av = arow[r];
            let dst = &mut acc[r * nr..(r + 1) * nr];
            for (d, bv) in dst.iter_mut().zip(brow) {
                *d += av * bv;
            }
        }
    }
}

/// C := A·B with fused weighted-double-checksum online ABFT. One error
/// per rank-K_C interval is located from the two column-space checksum
/// scans and corrected in place.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_abft_weighted(m: usize, n: usize, k: usize, a: &[f64],
                           b: &[f64], c: &mut [f64], params: &GemmParams,
                           inject: &[Strike]) -> FtReport {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let mut report = FtReport::none();
    if m == 0 || n == 0 {
        return report;
    }
    c.fill(0.0);
    let &GemmParams { mc, nc, kc, mr, nr } = params;

    let mut s1_enc = vec![0.0; n];
    let mut s2_enc = vec![0.0; n];
    let mut s1_ref = vec![0.0; n];
    let mut s2_ref = vec![0.0; n];

    let mut apack = vec![0.0; mc.div_ceil(mr) * mr * kc];
    let mut bpack = vec![0.0; nc.div_ceil(nr) * nr * kc];
    let mut acc = vec![0.0; mr * nr];
    let mut eta1 = vec![0.0; kc];
    let mut eta2 = vec![0.0; kc];
    // block-local accumulators (same cache-aliasing rationale as
    // abft_fused)
    let mut enc1_loc = vec![0.0; nc];
    let mut enc2_loc = vec![0.0; nc];
    let mut ref1_loc = vec![0.0; nc];
    let mut ref2_loc = vec![0.0; nc];
    let (mut max_a, mut max_b) = (0.0f64, 0.0f64);
    let mut corrected_tol = 0.0f64;

    let mut p0 = 0;
    let mut step = 0;
    while p0 < k {
        let kcb = kc.min(k - p0);
        let mut j0 = 0;
        while j0 < n {
            let ncb = nc.min(n - j0);
            pack_b_plain(b, n, p0, j0, kcb, ncb, nr, &mut bpack);
            max_b = max_b.max(super::abft_fused::max_abs(
                &bpack[..ncb.div_ceil(nr) * nr * kcb]));
            let mut i0 = 0;
            while i0 < m {
                let mcb = mc.min(m - i0);
                eta1[..kcb].fill(0.0);
                eta2[..kcb].fill(0.0);
                enc1_loc[..ncb].fill(0.0);
                enc2_loc[..ncb].fill(0.0);
                ref1_loc[..ncb].fill(0.0);
                ref2_loc[..ncb].fill(0.0);
                pack_a_weighted(a, k, i0, p0, mcb, kcb, mr, &mut apack,
                                &mut eta1[..kcb], &mut eta2[..kcb]);
                if j0 == 0 {
                    max_a = max_a.max(super::abft_fused::max_abs(
                        &apack[..mcb.div_ceil(mr) * mr * kcb]));
                }
                // encoded contributions: eta1·B̃ and eta2·B̃ over the
                // cache-hot packed buffer
                let mut jj = 0;
                while jj < ncb {
                    let cols = nr.min(ncb - jj);
                    let bp = &bpack[(jj / nr) * (nr * kcb)..][..nr * kcb];
                    for p in 0..kcb {
                        let e1 = eta1[p];
                        let e2 = eta2[p];
                        let brow = &bp[p * nr..p * nr + cols];
                        let d1 = &mut enc1_loc[jj..jj + cols];
                        for (d, bv) in d1.iter_mut().zip(brow) {
                            *d += e1 * bv;
                        }
                        let d2 = &mut enc2_loc[jj..jj + cols];
                        for (d, bv) in d2.iter_mut().zip(brow) {
                            *d += e2 * bv;
                        }
                    }
                    jj += nr;
                }
                // macro kernel + fused reference checksums
                let mut jj = 0;
                while jj < ncb {
                    let nrb = nr.min(ncb - jj);
                    let bp = &bpack[(jj / nr) * (nr * kcb)..][..nr * kcb];
                    let mut ii = 0;
                    while ii < mcb {
                        let mrb = mr.min(mcb - ii);
                        let ap = &apack[(ii / mr) * (mr * kcb)..][..mr * kcb];
                        micro_kernel(kcb, ap, bp, mr, nr, &mut acc);
                        for &(s, fi, fj, delta) in inject {
                            if s == step
                                && fi >= i0 + ii && fi < i0 + ii + mrb
                                && fj >= j0 + jj && fj < j0 + jj + nrb
                            {
                                acc[(fi - i0 - ii) * nr + (fj - j0 - jj)] +=
                                    delta;
                            }
                        }
                        for r in 0..mrb {
                            let gi = i0 + ii + r;
                            let wrow = (gi + 1) as f64;
                            let crow = &mut c[gi * n + j0 + jj..][..nrb];
                            let arow = &acc[r * nr..r * nr + nrb];
                            let r1 = &mut ref1_loc[jj..jj + nrb];
                            let r2 = &mut ref2_loc[jj..jj + nrb];
                            for (((cv, av), v1), v2) in crow
                                .iter_mut()
                                .zip(arow)
                                .zip(r1.iter_mut())
                                .zip(r2.iter_mut())
                            {
                                *cv += av;
                                *v1 += av;
                                *v2 += wrow * av;
                            }
                        }
                        ii += mr;
                    }
                    jj += nr;
                }
                for (g, l) in s1_enc[j0..j0 + ncb].iter_mut()
                    .zip(&enc1_loc[..ncb])
                {
                    *g += l;
                }
                for (g, l) in s2_enc[j0..j0 + ncb].iter_mut()
                    .zip(&enc2_loc[..ncb])
                {
                    *g += l;
                }
                for (g, l) in s1_ref[j0..j0 + ncb].iter_mut()
                    .zip(&ref1_loc[..ncb])
                {
                    *g += l;
                }
                for (g, l) in s2_ref[j0..j0 + ncb].iter_mut()
                    .zip(&ref2_loc[..ncb])
                {
                    *g += l;
                }
                i0 += mc;
            }
            j0 += nc;
        }
        // verification: scan s1; decode the row from Δs2/Δs1
        let tol = crate::ft::abft::round_off_threshold(
            max_a * max_b, k, n.max(m)) + corrected_tol;
        let mut j_err = None;
        let mut worst = tol;
        for j in 0..n {
            let d = (s1_ref[j] - s1_enc[j]).abs();
            if d > worst {
                worst = d;
                j_err = Some(j);
            }
        }
        if let Some(j) = j_err {
            let d1 = s1_ref[j] - s1_enc[j];
            let d2 = s2_ref[j] - s2_enc[j];
            let row = (d2 / d1).round() as isize - 1;
            if row >= 0 && (row as usize) < m {
                let i = row as usize;
                c[i * n + j] -= d1;
                s1_ref[j] -= d1;
                s2_ref[j] -= (i + 1) as f64 * d1;
                corrected_tol += d1.abs() * f64::EPSILON * 64.0
                    * (m as f64).max(1.0);
                report.errors_detected += 1;
                report.errors_corrected += 1;
            } else {
                // decoded row out of range: detected but uncorrectable
                // under the single-error model
                report.errors_detected += 1;
            }
        }
        p0 += kc;
        step += 1;
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure};
    use crate::util::matrix::{allclose, Matrix};

    #[test]
    fn weighted_matches_naive_clean() {
        check("abft-weighted-clean", 20, |g| {
            let m = g.dim(1, 48);
            let n = g.dim(1, 48);
            let k = g.dim(1, 48);
            let params = GemmParams {
                kc: [4, 8, 16][g.rng.below(3)],
                ..Default::default()
            };
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let mut want = vec![0.0; m * n];
            naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_weighted(m, n, k, &a.data, &b.data, &mut c,
                                          &params, &[]);
            ensure(rep == FtReport::none(),
                   format!("weighted clean flagged: {rep:?}"))?;
            ensure(allclose(&c, &want, 1e-9, 1e-9), "weighted clean wrong")
        });
    }

    #[test]
    fn weighted_locates_and_corrects() {
        check("abft-weighted-inject", 25, |g| {
            let m = g.dim(4, 64);
            let n = g.dim(4, 48);
            let k = g.dim(4, 64);
            let params = GemmParams { kc: 16, ..Default::default() };
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let mut want = vec![0.0; m * n];
            naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
            let steps = k.div_ceil(params.kc);
            let strike = (g.rng.below(steps), g.rng.below(m), g.rng.below(n),
                          g.rng.range(10.0, 1e5));
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_weighted(m, n, k, &a.data, &b.data, &mut c,
                                          &params, &[strike]);
            ensure(rep.errors_corrected == 1,
                   format!("weighted {rep:?} for {strike:?}"))?;
            ensure(allclose(&c, &want, 1e-8, 1e-8), "weighted not corrected")
        });
    }

    #[test]
    fn weighted_multi_interval() {
        let mut rng = crate::util::rng::Rng::new(0xD0);
        let (m, n, k) = (48, 40, 96);
        let params = GemmParams { kc: 16, ..Default::default() };
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let mut want = vec![0.0; m * n];
        naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
        let strikes: Vec<Strike> = (0..k.div_ceil(16))
            .step_by(2)
            .map(|s| (s, (s * 7) % m, (s * 11) % n, 5e4))
            .collect();
        let mut c = vec![0.0; m * n];
        let rep = dgemm_abft_weighted(m, n, k, &a.data, &b.data, &mut c,
                                      &params, &strikes);
        assert_eq!(rep.errors_corrected, strikes.len() as u64);
        assert!(allclose(&c, &want, 1e-8, 1e-8));
    }
}
