//! Checksum-based online ABFT for Level-3 BLAS (paper §2.1, §5).
//!
//! Two operating modes, matching the paper's Fig. 8 comparison:
//!
//! - **Fused** (§5.2): the Pallas kernel (or native fused GEMM) returns the
//!   four checksum vectors computed *inside* the GEMM data movement; this
//!   module only runs the O(n) verify/locate/correct step per rank-k
//!   update — the paper's negligible-overhead path.
//! - **Unfused** (§5.1, "ABFT on a third-party library"): this module
//!   computes the encoded checksums with separate DGEMV passes around an
//!   unprotected GEMM — the memory-bound extra traffic that costs ~15 %
//!   on AVX-512-class machines.
//!
//! The error model is the paper's: at most one error per verification
//! interval; detection via the row checksum, localization via row+column
//! checksums, correction by subtracting the decoded magnitude. No
//! checkpoint/rollback.

use crate::ft::FtReport;

/// Verification threshold (paper: "the round-off threshold").
///
/// For C = A·B with inner dimension k, element-wise round-off is bounded
/// by ~k·eps·max|A|·max|B|; checksum sums add another factor n. We use a
/// conservative multiple to avoid false positives on clean runs.
pub fn round_off_threshold(max_abs: f64, inner: usize, n: usize) -> f64 {
    let eps = f64::EPSILON;
    let scale = max_abs.max(1.0);
    scale * eps * ((inner * n) as f64).max(1.0) * 16.0
}

/// Encoded + reference checksum state for one matrix C under rank-k
/// accumulation (the caller carries this across update steps).
#[derive(Clone, Debug)]
pub struct ChecksumState {
    /// Running encoded row checksum: sum of A_panel · (B_panel · e).
    pub cr_enc: Vec<f64>,
    /// Running encoded column checksum: sum of (e^T · A_panel) · B_panel.
    pub cc_enc: Vec<f64>,
}

impl ChecksumState {
    /// Zero-initialized checksum state for an m×n output.
    pub fn zeros(m: usize, n: usize) -> Self {
        ChecksumState { cr_enc: vec![0.0; m], cc_enc: vec![0.0; n] }
    }

    /// Start from an existing C (C != 0 accumulation): encode C's sums.
    pub fn from_c(c: &[f64], m: usize, n: usize) -> Self {
        let mut s = Self::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let v = c[i * n + j];
                s.cr_enc[i] += v;
                s.cc_enc[j] += v;
            }
        }
        s
    }

    /// Accumulate a rank-k step's encoded contribution (from the fused
    /// kernel's dCr_enc/dCc_enc outputs, or from `encode_panel`).
    pub fn accumulate(&mut self, dcr: &[f64], dcc: &[f64]) {
        for (a, b) in self.cr_enc.iter_mut().zip(dcr) {
            *a += b;
        }
        for (a, b) in self.cc_enc.iter_mut().zip(dcc) {
            *a += b;
        }
    }
}

/// A located error: position and decoded magnitude.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LocatedError {
    /// Row of the corrupted element.
    pub i: usize,
    /// Column of the corrupted element.
    pub j: usize,
    /// Decoded additive error magnitude.
    pub magnitude: f64,
}

/// Compare reference vs encoded checksums; locate a single error.
///
/// `cr_ref`/`cc_ref` are the sums of the *actual* C; `state` holds the
/// predictions derived from A and B. Returns None when they agree within
/// `tol` (paper: check the row checksum first; only consult the column
/// checksum when a disagreement is found).
pub fn verify(state: &ChecksumState, cr_ref: &[f64], cc_ref: &[f64],
              tol: f64) -> Option<LocatedError> {
    let mut i_err = None;
    let mut worst = tol;
    for (i, (r, e)) in cr_ref.iter().zip(&state.cr_enc).enumerate() {
        let d = (r - e).abs();
        if d > worst {
            worst = d;
            i_err = Some(i);
        }
    }
    let i = i_err?;
    // localize the column
    let mut j_err = 0;
    let mut worst_c = 0.0;
    for (j, (r, e)) in cc_ref.iter().zip(&state.cc_enc).enumerate() {
        let d = (r - e).abs();
        if d > worst_c {
            worst_c = d;
            j_err = j;
        }
    }
    Some(LocatedError {
        i,
        j: j_err,
        magnitude: cr_ref[i] - state.cr_enc[i],
    })
}

/// Correct a located error in place: C[i, j] -= magnitude.
pub fn correct(c: &mut [f64], n: usize, e: &LocatedError) {
    c[e.i * n + e.j] -= e.magnitude;
}

/// Verify-and-correct one rank-k step; returns the FT report.
pub fn verify_and_correct(c: &mut [f64], n: usize, state: &ChecksumState,
                          cr_ref: &[f64], cc_ref: &[f64], tol: f64) -> FtReport {
    match verify(state, cr_ref, cc_ref, tol) {
        Some(err) => {
            correct(c, n, &err);
            FtReport { errors_detected: 1, errors_corrected: 1 }
        }
        None => FtReport::none(),
    }
}

// --------------------------------------------------------------- unfused

/// Encoded checksum contribution of one rank-k panel, computed with
/// explicit DGEMV passes over A_panel/B_panel — the *unfused* path:
/// dCr = A_panel · (B_panel e), dCc = (e^T A_panel) · B_panel.
pub fn encode_panel(a: &[f64], b: &[f64], m: usize, kc: usize, n: usize)
                    -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), m * kc);
    assert_eq!(b.len(), kc * n);
    // B_panel · e  (row sums of B)
    let mut be = vec![0.0; kc];
    for (p, bev) in be.iter_mut().enumerate() {
        *bev = b[p * n..(p + 1) * n].iter().sum();
    }
    // dCr = A · be
    let mut dcr = vec![0.0; m];
    crate::blas::level2::dgemv(m, kc, 1.0, a, &be, 0.0, &mut dcr);
    // e^T A  (column sums of A)
    let mut eta = vec![0.0; kc];
    for r in 0..m {
        for (p, ev) in eta.iter_mut().enumerate() {
            *ev += a[r * kc + p];
        }
    }
    // dCc = eta · B
    let mut dcc = vec![0.0; n];
    for p in 0..kc {
        let ep = eta[p];
        for (j, dv) in dcc.iter_mut().enumerate() {
            *dv += ep * b[p * n + j];
        }
    }
    (dcr, dcc)
}

/// Reference checksums of an actual C, computed with explicit passes —
/// the unfused path's per-interval O(n^2) memory traffic the paper's
/// fusion eliminates.
pub fn reference_checksums(c: &[f64], m: usize, n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut cr = vec![0.0; m];
    let mut cc = vec![0.0; n];
    for i in 0..m {
        let row = &c[i * n..(i + 1) * n];
        let mut acc = 0.0;
        for (j, v) in row.iter().enumerate() {
            acc += v;
            cc[j] += v;
        }
        cr[i] = acc;
    }
    (cr, cc)
}

/// Unfused online-ABFT DGEMM on top of an arbitrary unprotected GEMM
/// backend (the paper's §5.1 baseline). `gemm` computes
/// C += A_panel · B_panel for the given panel. `inject` optionally
/// corrupts C after a chosen step (step, i, j, delta).
#[allow(clippy::too_many_arguments)]
pub fn dgemm_abft_unfused<F>(m: usize, n: usize, k: usize, kc: usize,
                             a: &[f64], b: &[f64], c: &mut [f64],
                             mut gemm: F,
                             inject: Option<(usize, usize, usize, f64)>)
                             -> FtReport
where
    F: FnMut(&[f64], &[f64], &mut [f64], usize, usize),
{
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let mut state = ChecksumState::from_c(c, m, n);
    let mut report = FtReport::none();
    let max_ab = a.iter().chain(b.iter()).fold(0.0f64, |mx, v| mx.max(v.abs()));
    // a corrected error of magnitude M leaves ~eps·|M| residual in C —
    // widen later intervals' threshold so it is not re-detected forever
    let mut corrected_tol = 0.0f64;
    let mut p0 = 0;
    let mut step = 0;
    while p0 < k {
        let kcb = kc.min(k - p0);
        // slice the panels (packing pass — extra traffic, unfused)
        let mut ap = vec![0.0; m * kcb];
        for i in 0..m {
            ap[i * kcb..(i + 1) * kcb]
                .copy_from_slice(&a[i * k + p0..i * k + p0 + kcb]);
        }
        let bp = &b[p0 * n..(p0 + kcb) * n];
        // encoded checksums via explicit GEMV passes
        let (dcr, dcc) = encode_panel(&ap, bp, m, kcb, n);
        state.accumulate(&dcr, &dcc);
        // the unprotected third-party GEMM
        gemm(&ap, bp, c, m, kcb);
        // simulated transient fault
        if let Some((s, i, j, delta)) = inject {
            if s == step {
                c[i * n + j] += delta;
            }
        }
        // reference checksums via explicit passes over all of C
        let (cr_ref, cc_ref) = reference_checksums(c, m, n);
        let tol = round_off_threshold(max_ab * max_ab, k, n.max(m))
            + corrected_tol;
        let step_rep = match verify(&state, &cr_ref, &cc_ref, tol) {
            Some(err) => {
                correct(c, n, &err);
                corrected_tol += err.magnitude.abs() * f64::EPSILON * 64.0;
                FtReport { errors_detected: 1, errors_corrected: 1 }
            }
            None => FtReport::none(),
        };
        report.merge(step_rep);
        p0 += kcb;
        step += 1;
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure};
    use crate::util::matrix::{allclose, Matrix};

    #[test]
    fn clean_run_verifies() {
        check("abft-clean", 20, |g| {
            let m = g.dim(4, 40);
            let n = g.dim(4, 40);
            let k = g.dim(4, 40);
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let mut c = vec![0.0; m * n];
            naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut c);
            let mut state = ChecksumState::zeros(m, n);
            let (dcr, dcc) = encode_panel(&a.data, &b.data, m, k, n);
            state.accumulate(&dcr, &dcc);
            let (cr, cc) = reference_checksums(&c, m, n);
            let tol = round_off_threshold(
                a.max_abs() * b.max_abs(), k, n.max(m));
            ensure(verify(&state, &cr, &cc, tol).is_none(),
                   "false positive on clean gemm")
        });
    }

    #[test]
    fn single_error_located_and_corrected() {
        check("abft-locate", 30, |g| {
            let m = g.dim(4, 40);
            let n = g.dim(4, 40);
            let k = g.dim(4, 40);
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let mut clean = vec![0.0; m * n];
            naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut clean);
            let (ei, ej) = (g.rng.below(m), g.rng.below(n));
            let delta = g.rng.range(0.5, 1e6);
            let mut c = clean.clone();
            c[ei * n + ej] += delta;
            let mut state = ChecksumState::zeros(m, n);
            let (dcr, dcc) = encode_panel(&a.data, &b.data, m, k, n);
            state.accumulate(&dcr, &dcc);
            let (cr, cc) = reference_checksums(&c, m, n);
            let tol = round_off_threshold(
                a.max_abs() * b.max_abs(), k, n.max(m));
            let err = verify(&state, &cr, &cc, tol)
                .ok_or("error not detected")?;
            ensure(err.i == ei && err.j == ej,
                   format!("located ({},{}) wanted ({ei},{ej})", err.i, err.j))?;
            correct(&mut c, n, &err);
            ensure(allclose(&c, &clean, 1e-7, 1e-6 + delta.abs() * 1e-11),
                   "correction did not restore C")
        });
    }

    #[test]
    fn unfused_abft_corrects_midstream_error() {
        check("abft-unfused", 15, |g| {
            let m = g.dim(8, 48);
            let n = g.dim(8, 48);
            let k = g.dim(16, 64);
            let kc = 8;
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let mut clean = vec![0.0; m * n];
            naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut clean);
            let steps = k.div_ceil(kc);
            let inject = (g.rng.below(steps), g.rng.below(m), g.rng.below(n),
                          g.rng.range(1.0, 1e5));
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_unfused(
                m, n, k, kc, &a.data, &b.data, &mut c,
                |ap, bp, c, mm, kk| {
                    naive::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, c);
                },
                Some(inject),
            );
            ensure(rep.errors_detected == 1 && rep.errors_corrected == 1,
                   format!("report {rep:?}"))?;
            ensure(allclose(&c, &clean, 1e-7, 1e-6),
                   "unfused abft failed to correct")
        });
    }

    #[test]
    fn unfused_abft_clean_no_false_positives() {
        let mut rng = crate::util::rng::Rng::new(77);
        let (m, n, k) = (32, 32, 64);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let mut c = vec![0.0; m * n];
        let rep = dgemm_abft_unfused(
            m, n, k, 16, &a.data, &b.data, &mut c,
            |ap, bp, c, mm, kk| naive::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, c),
            None,
        );
        assert_eq!(rep, FtReport::none());
    }

    #[test]
    fn threshold_scales() {
        assert!(round_off_threshold(1.0, 64, 64) <
                round_off_threshold(1e6, 64, 64));
        assert!(round_off_threshold(1.0, 64, 64) <
                round_off_threshold(1.0, 4096, 4096));
    }
}
