//! Deterministic fault-injection substrate (DESIGN.md substitution #3).
//!
//! The paper injects errors "from a source code level to minimize the
//! performance impact" — one error every k iterations, 20 per routine
//! run, with positions/magnitudes chosen per run. This module generates
//! those injection plans deterministically from a seed so experiments are
//! reproducible, and converts them to the operand format the AOT kernels
//! expect ([flag, idx..., delta] f64 vectors).

use crate::util::rng::Rng;

/// One planned fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Fault {
    /// Which call (or rank-k step / panel step) the fault strikes.
    pub step: usize,
    /// Position within the output (row for vectors, (i, j) for matrices).
    pub i: usize,
    /// Column within the output (0 for vectors).
    pub j: usize,
    /// Additive magnitude — the flipped-bit value delta.
    pub delta: f64,
}

/// Injection configuration for an experiment run.
#[derive(Clone, Debug)]
pub struct InjectorConfig {
    /// RNG seed; plans are deterministic given the config.
    pub seed: u64,
    /// Total faults to inject across the run (paper: 20 per routine).
    pub count: usize,
    /// Magnitude range (log-uniform).
    pub min_magnitude: f64,
    /// Upper magnitude bound.
    pub max_magnitude: f64,
}

impl Default for InjectorConfig {
    fn default() -> Self {
        InjectorConfig {
            seed: 0xF417,
            count: 20,
            min_magnitude: 1.0,
            max_magnitude: 1e6,
        }
    }
}

/// Plans and serves faults for a run of `total_steps` kernel invocations
/// over an (m x n) output (n = 1 for vector routines).
#[derive(Clone, Debug)]
pub struct Injector {
    plan: Vec<Fault>,
    cursor: usize,
}

impl Injector {
    /// Evenly spread `config.count` faults over `total_steps` (the paper's
    /// "one error every k iterations"), with randomized positions and
    /// log-uniform magnitudes.
    pub fn plan(config: &InjectorConfig, total_steps: usize, m: usize,
                n: usize) -> Self {
        let mut rng = Rng::new(config.seed);
        let count = config.count.min(total_steps);
        let stride = if count == 0 { 1 } else { total_steps / count.max(1) };
        let lo = config.min_magnitude.ln();
        let hi = config.max_magnitude.ln();
        let plan = (0..count)
            .map(|f| Fault {
                step: (f * stride.max(1)).min(total_steps.saturating_sub(1)),
                i: rng.below(m.max(1)),
                j: rng.below(n.max(1)),
                delta: rng.range(lo, hi).exp()
                    * if rng.uniform() < 0.5 { -1.0 } else { 1.0 },
            })
            .collect();
        Injector { plan, cursor: 0 }
    }

    /// An injector with nothing planned.
    pub fn empty() -> Self {
        Injector { plan: Vec::new(), cursor: 0 }
    }

    /// Total strikes in the plan.
    pub fn planned(&self) -> usize {
        self.plan.len()
    }

    /// The fault striking `step`, if any (consumes it).
    pub fn take(&mut self, step: usize) -> Option<Fault> {
        if self.cursor < self.plan.len() && self.plan[self.cursor].step == step {
            let f = self.plan[self.cursor];
            self.cursor += 1;
            Some(f)
        } else {
            None
        }
    }

    /// Strikes not yet taken.
    pub fn remaining(&self) -> usize {
        self.plan.len() - self.cursor
    }
}

/// Serialize a fault to the 3-operand format of the L1 DMR kernels:
/// [flag, idx, delta].
pub fn to_inject3(fault: Option<Fault>) -> [f64; 3] {
    match fault {
        Some(f) => [1.0, f.i as f64, f.delta],
        None => [0.0, 0.0, 0.0],
    }
}

/// Serialize to the 4-operand format of the GEMV-DMR / ABFT kernels:
/// [flag, i, j, delta].
pub fn to_inject4(fault: Option<Fault>) -> [f64; 4] {
    match fault {
        Some(f) => [1.0, f.i as f64, f.j as f64, f.delta],
        None => [0.0, 0.0, 0.0, 0.0],
    }
}

/// Serialize to the 5-operand format of the FT-TRSM kernel:
/// [flag, step, i, j, delta].
pub fn to_inject5(fault: Option<Fault>) -> [f64; 5] {
    match fault {
        Some(f) => [1.0, f.step as f64, f.i as f64, f.j as f64, f.delta],
        None => [0.0; 5],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_deterministic() {
        let cfg = InjectorConfig::default();
        let a = Injector::plan(&cfg, 100, 64, 64);
        let b = Injector::plan(&cfg, 100, 64, 64);
        assert_eq!(a.plan, b.plan);
    }

    #[test]
    fn plan_spreads_steps() {
        let cfg = InjectorConfig { count: 10, ..Default::default() };
        let inj = Injector::plan(&cfg, 100, 8, 8);
        assert_eq!(inj.planned(), 10);
        let steps: Vec<usize> = inj.plan.iter().map(|f| f.step).collect();
        assert!(steps.windows(2).all(|w| w[0] < w[1]), "{steps:?}");
        assert!(*steps.last().unwrap() < 100);
    }

    #[test]
    fn take_consumes_in_order() {
        let cfg = InjectorConfig { count: 4, ..Default::default() };
        let mut inj = Injector::plan(&cfg, 8, 4, 4);
        let mut hits = 0;
        for step in 0..8 {
            if inj.take(step).is_some() {
                hits += 1;
            }
        }
        assert_eq!(hits, 4);
        assert_eq!(inj.remaining(), 0);
    }

    #[test]
    fn positions_in_range() {
        let cfg = InjectorConfig { count: 50, ..Default::default() };
        let inj = Injector::plan(&cfg, 50, 13, 7);
        for f in &inj.plan {
            assert!(f.i < 13 && f.j < 7);
            let mag = f.delta.abs();
            assert!((1.0..=1e6).contains(&mag), "delta={}", f.delta);
        }
    }

    #[test]
    fn count_capped_by_steps() {
        let cfg = InjectorConfig { count: 100, ..Default::default() };
        let inj = Injector::plan(&cfg, 5, 4, 4);
        assert_eq!(inj.planned(), 5);
    }

    #[test]
    fn serializers() {
        let f = Fault { step: 3, i: 2, j: 5, delta: -7.5 };
        assert_eq!(to_inject3(Some(f)), [1.0, 2.0, -7.5]);
        assert_eq!(to_inject4(Some(f)), [1.0, 2.0, 5.0, -7.5]);
        assert_eq!(to_inject5(Some(f)), [1.0, 3.0, 2.0, 5.0, -7.5]);
        assert_eq!(to_inject3(None)[0], 0.0);
    }
}
