//! Deterministic fault-injection substrate (DESIGN.md substitution #3).
//!
//! The paper injects errors "from a source code level to minimize the
//! performance impact" — one error every k iterations, 20 per routine
//! run, with positions/magnitudes chosen per run. This module generates
//! those injection plans deterministically from a seed so experiments are
//! reproducible, and converts them to the operand format the AOT kernels
//! expect ([flag, idx..., delta] f64 vectors).
//!
//! Two injection modes live here:
//!
//! - **Per-call plans** ([`Injector`]): a fixed count of faults spread
//!   over one run's call stream — the shape the paper's §6 experiments
//!   use, and what `ftblas run --inject` / `serve --inject` arm.
//! - **Campaigns** ([`InjectionCampaign`]): a seeded, *rate-based*
//!   cluster-wide schedule (target errors per minute) for sustained
//!   soak runs — the "hundreds of errors injected per minute" regime of
//!   paper §6 and FT-GEMM's sustained-injection argument. The schedule
//!   is a pure function of `(campaign seed, KernelId, occurrence)`
//!   ([`CampaignConfig::is_strike`]), so it is topology-independent:
//!   however the serving tier shards, grows, or shrinks, each kernel's
//!   executions see exactly the same strike sequence, and the
//!   cluster-wide occurrence counters guarantee a migrated kernel
//!   continues its sequence instead of replaying it (no
//!   double-injection after a re-salt migration).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::registry::{KernelId, Scheme};
use crate::util::rng::Rng;

/// SplitMix64 finalizer — the stateless, position-addressable hash
/// behind the campaign schedule (an RNG stream would have to be drawn
/// in order; the schedule must answer "does occurrence n strike?" for
/// any n directly).
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// 64-bit golden-ratio stride (decorrelates per-kernel hash lanes).
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// One planned fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Fault {
    /// Which call (or rank-k step / panel step) the fault strikes.
    pub step: usize,
    /// Position within the output (row for vectors, (i, j) for matrices).
    pub i: usize,
    /// Column within the output (0 for vectors).
    pub j: usize,
    /// Additive magnitude — the flipped-bit value delta.
    pub delta: f64,
}

/// Injection configuration for an experiment run.
#[derive(Clone, Debug)]
pub struct InjectorConfig {
    /// RNG seed; plans are deterministic given the config.
    pub seed: u64,
    /// Total faults to inject across the run (paper: 20 per routine).
    pub count: usize,
    /// Magnitude range (log-uniform).
    pub min_magnitude: f64,
    /// Upper magnitude bound.
    pub max_magnitude: f64,
}

impl Default for InjectorConfig {
    fn default() -> Self {
        InjectorConfig {
            seed: 0xF417,
            count: 20,
            min_magnitude: 1.0,
            max_magnitude: 1e6,
        }
    }
}

/// Plans and serves faults for a run of `total_steps` kernel invocations
/// over an (m x n) output (n = 1 for vector routines).
#[derive(Clone, Debug)]
pub struct Injector {
    plan: Vec<Fault>,
    cursor: usize,
}

impl Injector {
    /// Evenly spread `config.count` faults over `total_steps` (the paper's
    /// "one error every k iterations"), with randomized positions and
    /// log-uniform magnitudes.
    pub fn plan(config: &InjectorConfig, total_steps: usize, m: usize,
                n: usize) -> Self {
        let mut rng = Rng::new(config.seed);
        let count = config.count.min(total_steps);
        let stride = if count == 0 { 1 } else { total_steps / count.max(1) };
        let lo = config.min_magnitude.ln();
        let hi = config.max_magnitude.ln();
        let plan = (0..count)
            .map(|f| Fault {
                step: (f * stride.max(1)).min(total_steps.saturating_sub(1)),
                i: rng.below(m.max(1)),
                j: rng.below(n.max(1)),
                delta: rng.range(lo, hi).exp()
                    * if rng.uniform() < 0.5 { -1.0 } else { 1.0 },
            })
            .collect();
        Injector { plan, cursor: 0 }
    }

    /// An injector with nothing planned.
    pub fn empty() -> Self {
        Injector { plan: Vec::new(), cursor: 0 }
    }

    /// Total strikes in the plan.
    pub fn planned(&self) -> usize {
        self.plan.len()
    }

    /// The fault striking `step`, if any (consumes it).
    pub fn take(&mut self, step: usize) -> Option<Fault> {
        if self.cursor < self.plan.len() && self.plan[self.cursor].step == step {
            let f = self.plan[self.cursor];
            self.cursor += 1;
            Some(f)
        } else {
            None
        }
    }

    /// Strikes not yet taken.
    pub fn remaining(&self) -> usize {
        self.plan.len() - self.cursor
    }
}

/// Which protection paths a campaign strikes. Campaigns are
/// **scheme-aware**: a strike on a kernel whose scheme cannot detect it
/// (`Scheme::None`) would escape by construction and say nothing about
/// the FT machinery, so unprotected kernels are never targeted — a
/// campaign measures the protection, not the absence of it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CampaignTarget {
    /// Every protected path: DMR, all ABFT flavors, and FT-TRSM.
    AllProtected,
    /// The duplicate-and-verify Level-1/2 paths only (paper §4).
    Dmr,
    /// Every checksum path: fused, unfused, and weighted ABFT plus
    /// FT-TRSM (paper §5).
    Abft,
    /// Only the fused online-ABFT kernels (paper §5.2).
    Fused,
}

impl CampaignTarget {
    /// Every target, in CLI/report order.
    pub const ALL: [CampaignTarget; 4] = [
        CampaignTarget::AllProtected,
        CampaignTarget::Dmr,
        CampaignTarget::Abft,
        CampaignTarget::Fused,
    ];

    /// Whether a kernel running `scheme` is inside this target set.
    /// `Scheme::None` is outside every set.
    pub fn admits(&self, scheme: Scheme) -> bool {
        match self {
            CampaignTarget::AllProtected => scheme != Scheme::None,
            CampaignTarget::Dmr => scheme == Scheme::Dmr,
            CampaignTarget::Abft => matches!(
                scheme,
                Scheme::AbftFused | Scheme::AbftUnfused
                    | Scheme::AbftWeighted | Scheme::FtTrsm
            ),
            CampaignTarget::Fused => scheme == Scheme::AbftFused,
        }
    }

    /// CLI/report name of the target set.
    pub fn name(&self) -> &'static str {
        match self {
            CampaignTarget::AllProtected => "all",
            CampaignTarget::Dmr => "dmr",
            CampaignTarget::Abft => "abft",
            CampaignTarget::Fused => "fused",
        }
    }

    /// Parse a target name (the soak CLI's `--target`).
    pub fn by_name(s: &str) -> Option<CampaignTarget> {
        match s {
            "all" | "all-protected" => Some(CampaignTarget::AllProtected),
            "dmr" => Some(CampaignTarget::Dmr),
            "abft" => Some(CampaignTarget::Abft),
            "fused" => Some(CampaignTarget::Fused),
            _ => None,
        }
    }
}

/// Configuration of an injection campaign. The schedule half
/// ([`CampaignConfig::is_strike`] / [`CampaignConfig::fault_at`]) is a
/// pure function of this config, so two campaigns built from equal
/// configs plant identical faults regardless of cluster topology.
#[derive(Clone, Debug)]
pub struct CampaignConfig {
    /// Campaign seed; the per-kernel schedule derives from it.
    pub seed: u64,
    /// Cluster-wide target injection rate, in errors per minute. The
    /// realized rate is capped here by a token bucket that refills
    /// continuously; candidate strikes beyond the budget are
    /// *suppressed* (counted, never injected), so a fast tier does not
    /// overshoot the target.
    pub rate_per_min: f64,
    /// Candidate stride: every `stride`-th eligible execution of a
    /// kernel is a candidate strike — the paper's "one error every k
    /// iterations" — at a per-kernel phase derived from the seed (so
    /// different kernels strike on different beats).
    pub stride: u64,
    /// Which protection paths the campaign strikes.
    pub target: CampaignTarget,
    /// Magnitude range (log-uniform), kept well above checksum
    /// tolerances so a planted fault is unambiguously detectable.
    pub min_magnitude: f64,
    /// Upper magnitude bound.
    pub max_magnitude: f64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            seed: 0xCA4A16,
            rate_per_min: 120.0,
            stride: 4,
            target: CampaignTarget::AllProtected,
            min_magnitude: 1e2,
            max_magnitude: 1e6,
        }
    }
}

impl CampaignConfig {
    /// This kernel's candidate phase in `[0, stride)`.
    fn phase(&self, kernel: KernelId) -> u64 {
        mix64(self.seed ^ (kernel.0 as u64).wrapping_mul(GOLDEN))
            % self.stride.max(1)
    }

    /// Whether the `occurrence`-th eligible execution of `kernel`
    /// (0-based, counted cluster-wide) is a candidate strike. Pure in
    /// `(config, kernel, occurrence)` — topology-independent, which is
    /// what makes the schedule partition exactly across shards: routing
    /// decides *where* a kernel runs, never *whether* it is struck.
    pub fn is_strike(&self, kernel: KernelId, occurrence: u64) -> bool {
        occurrence % self.stride.max(1) == self.phase(kernel)
    }

    /// The fault the schedule plants on a candidate occurrence, scaled
    /// into an `m × n` output. Deterministic in `(config, kernel,
    /// occurrence, m, n)`; the step lands in a small range the stepped
    /// kernels clamp into their panel count.
    pub fn fault_at(&self, kernel: KernelId, occurrence: u64, m: usize,
                    n: usize) -> Fault {
        let h1 = mix64(self.seed
                       ^ mix64(((kernel.0 as u64) << 32) | occurrence));
        let h2 = mix64(h1);
        let h3 = mix64(h2);
        let lo = self.min_magnitude.max(f64::MIN_POSITIVE).ln();
        let hi = self.max_magnitude.max(self.min_magnitude).ln();
        let u = (h3 >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let magnitude = (lo + (hi - lo) * u).exp();
        Fault {
            step: ((h1 >> 16) & 0xF) as usize,
            i: (h1 as usize) % m.max(1),
            j: (h2 as usize) % n.max(1),
            delta: if h3 & 1 == 0 { magnitude } else { -magnitude },
        }
    }
}

/// A live, cluster-wide injection campaign: the runtime state (clock,
/// rate budget, per-kernel occurrence counters) around the pure
/// [`CampaignConfig`] schedule.
///
/// One instance is shared — via the cluster's `Arc<Router>` — by every
/// shard, *including shards the autoscaler spawns mid-run*: a new shard
/// inherits its slice of the campaign (the strikes of whatever kernels
/// rendezvous routing assigns it) with no hand-off protocol, because
/// the schedule never depended on the topology in the first place. The
/// per-kernel occurrence counters are likewise cluster-wide, so a
/// kernel migrated to a fresh-salted shard *continues* its occurrence
/// sequence — the schedule entries it already consumed can never fire
/// a second time.
#[derive(Debug)]
pub struct InjectionCampaign {
    cfg: CampaignConfig,
    /// Campaign clock: the rate budget accrues from construction.
    start: Instant,
    /// Cluster-wide occurrence counters, indexed by `KernelId`. Each
    /// eligible execution claims the next index for its kernel
    /// regardless of which shard runs it.
    occurrences: Mutex<Vec<u64>>,
    /// Faults actually armed (the ledger's `errors_injected` mirror).
    injected: AtomicU64,
    /// Candidate strikes the rate gate refused (budget spent).
    suppressed: AtomicU64,
}

impl InjectionCampaign {
    /// Start a campaign; the rate budget begins accruing now.
    pub fn new(cfg: CampaignConfig) -> InjectionCampaign {
        InjectionCampaign {
            cfg,
            start: Instant::now(),
            occurrences: Mutex::new(Vec::new()),
            injected: AtomicU64::new(0),
            suppressed: AtomicU64::new(0),
        }
    }

    /// The campaign's configuration (and thereby its pure schedule).
    pub fn config(&self) -> &CampaignConfig {
        &self.cfg
    }

    /// Claim the next cluster-wide occurrence index of `kernel`.
    fn claim(&self, kernel: KernelId) -> u64 {
        let mut occ = self.occurrences.lock().unwrap();
        let idx = kernel.0 as usize;
        if occ.len() <= idx {
            occ.resize(idx + 1, 0);
        }
        let n = occ[idx];
        occ[idx] = n + 1;
        n
    }

    /// Arm a fault for one execution of `kernel` over a `dim × dim`
    /// (or `dim`-long) output. Returns `None` when the kernel's scheme
    /// is outside the campaign's target set (no occurrence consumed),
    /// when the occurrence is not a candidate on the schedule, or when
    /// the rate budget is spent (the candidate is counted as
    /// suppressed).
    pub fn arm(&self, kernel: KernelId, scheme: Scheme, dim: usize)
               -> Option<Fault> {
        if !self.cfg.target.admits(scheme) {
            return None;
        }
        let occurrence = self.claim(kernel);
        if !self.cfg.is_strike(kernel, occurrence) {
            return None;
        }
        // token bucket: budget refills continuously at the target rate;
        // +1 lets the first candidate fire at t = 0 (an f64→u64 cast
        // saturates, so an infinite rate means an unbounded budget)
        let budget = (self.cfg.rate_per_min.max(0.0) / 60.0
                      * self.start.elapsed().as_secs_f64()) as u64;
        let budget = budget.saturating_add(1);
        let mut cur = self.injected.load(Ordering::Relaxed);
        loop {
            if cur >= budget {
                self.suppressed.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match self.injected.compare_exchange_weak(
                cur, cur + 1, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        Some(self.cfg.fault_at(kernel, occurrence, dim, dim))
    }

    /// Faults armed so far (the cluster ledger's `errors_injected`
    /// must agree with this at rest — the soak gate checks it).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Candidate strikes the rate gate refused.
    pub fn suppressed(&self) -> u64 {
        self.suppressed.load(Ordering::Relaxed)
    }

    /// Eligible executions of `kernel` observed so far, cluster-wide.
    pub fn occurrences_of(&self, kernel: KernelId) -> u64 {
        let occ = self.occurrences.lock().unwrap();
        occ.get(kernel.0 as usize).copied().unwrap_or(0)
    }
}

/// Serialize a fault to the 3-operand format of the L1 DMR kernels:
/// [flag, idx, delta].
pub fn to_inject3(fault: Option<Fault>) -> [f64; 3] {
    match fault {
        Some(f) => [1.0, f.i as f64, f.delta],
        None => [0.0, 0.0, 0.0],
    }
}

/// Serialize to the 4-operand format of the GEMV-DMR / ABFT kernels:
/// [flag, i, j, delta].
pub fn to_inject4(fault: Option<Fault>) -> [f64; 4] {
    match fault {
        Some(f) => [1.0, f.i as f64, f.j as f64, f.delta],
        None => [0.0, 0.0, 0.0, 0.0],
    }
}

/// Serialize to the 5-operand format of the FT-TRSM kernel:
/// [flag, step, i, j, delta].
pub fn to_inject5(fault: Option<Fault>) -> [f64; 5] {
    match fault {
        Some(f) => [1.0, f.step as f64, f.i as f64, f.j as f64, f.delta],
        None => [0.0; 5],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_deterministic() {
        let cfg = InjectorConfig::default();
        let a = Injector::plan(&cfg, 100, 64, 64);
        let b = Injector::plan(&cfg, 100, 64, 64);
        assert_eq!(a.plan, b.plan);
    }

    #[test]
    fn plan_spreads_steps() {
        let cfg = InjectorConfig { count: 10, ..Default::default() };
        let inj = Injector::plan(&cfg, 100, 8, 8);
        assert_eq!(inj.planned(), 10);
        let steps: Vec<usize> = inj.plan.iter().map(|f| f.step).collect();
        assert!(steps.windows(2).all(|w| w[0] < w[1]), "{steps:?}");
        assert!(*steps.last().unwrap() < 100);
    }

    #[test]
    fn take_consumes_in_order() {
        let cfg = InjectorConfig { count: 4, ..Default::default() };
        let mut inj = Injector::plan(&cfg, 8, 4, 4);
        let mut hits = 0;
        for step in 0..8 {
            if inj.take(step).is_some() {
                hits += 1;
            }
        }
        assert_eq!(hits, 4);
        assert_eq!(inj.remaining(), 0);
    }

    #[test]
    fn positions_in_range() {
        let cfg = InjectorConfig { count: 50, ..Default::default() };
        let inj = Injector::plan(&cfg, 50, 13, 7);
        for f in &inj.plan {
            assert!(f.i < 13 && f.j < 7);
            let mag = f.delta.abs();
            assert!((1.0..=1e6).contains(&mag), "delta={}", f.delta);
        }
    }

    #[test]
    fn count_capped_by_steps() {
        let cfg = InjectorConfig { count: 100, ..Default::default() };
        let inj = Injector::plan(&cfg, 5, 4, 4);
        assert_eq!(inj.planned(), 5);
    }

    #[test]
    fn serializers() {
        let f = Fault { step: 3, i: 2, j: 5, delta: -7.5 };
        assert_eq!(to_inject3(Some(f)), [1.0, 2.0, -7.5]);
        assert_eq!(to_inject4(Some(f)), [1.0, 2.0, 5.0, -7.5]);
        assert_eq!(to_inject5(Some(f)), [1.0, 3.0, 2.0, 5.0, -7.5]);
        assert_eq!(to_inject3(None)[0], 0.0);
    }

    fn unbounded() -> CampaignConfig {
        CampaignConfig { rate_per_min: f64::INFINITY, ..Default::default() }
    }

    /// The campaign schedule is a pure function: every `stride`-th
    /// occurrence of a kernel is a candidate, at a seed-derived
    /// per-kernel phase, identically across config clones.
    #[test]
    fn campaign_schedule_is_deterministic_and_stride_spaced() {
        let cfg = CampaignConfig { stride: 5, ..unbounded() };
        let rebuilt = CampaignConfig { stride: 5, ..unbounded() };
        for kid in [0u16, 3, 17, 79] {
            let k = KernelId(kid);
            let hits: Vec<u64> =
                (0..100).filter(|&o| cfg.is_strike(k, o)).collect();
            assert_eq!(hits.len(), 20, "stride 5 over 100 occurrences");
            assert!(hits[0] < 5, "phase lives inside the first stride");
            assert!(hits.windows(2).all(|w| w[1] - w[0] == 5));
            let again: Vec<u64> =
                (0..100).filter(|&o| rebuilt.is_strike(k, o)).collect();
            assert_eq!(hits, again);
        }
        // different seeds move the phases
        let other = CampaignConfig { seed: cfg.seed ^ 1, ..cfg.clone() };
        assert!((0u16..64).any(|kid| {
            let k = KernelId(kid);
            (0..5).find(|&o| cfg.is_strike(k, o))
                != (0..5).find(|&o| other.is_strike(k, o))
        }));
    }

    /// Scheme-aware targeting: unprotected kernels are never struck
    /// (and consume no occurrence), and the named subsets admit exactly
    /// their schemes.
    #[test]
    fn campaign_targets_are_scheme_aware() {
        for t in CampaignTarget::ALL {
            assert!(!t.admits(Scheme::None), "{:?} must skip unprotected", t);
            assert_eq!(CampaignTarget::by_name(t.name()), Some(t));
        }
        assert!(CampaignTarget::AllProtected.admits(Scheme::Dmr));
        assert!(CampaignTarget::AllProtected.admits(Scheme::FtTrsm));
        assert!(CampaignTarget::Dmr.admits(Scheme::Dmr));
        assert!(!CampaignTarget::Dmr.admits(Scheme::AbftFused));
        assert!(CampaignTarget::Abft.admits(Scheme::AbftWeighted));
        assert!(!CampaignTarget::Abft.admits(Scheme::Dmr));
        assert!(CampaignTarget::Fused.admits(Scheme::AbftFused));
        assert!(!CampaignTarget::Fused.admits(Scheme::AbftUnfused));
        assert!(CampaignTarget::by_name("storm").is_none());

        let c = InjectionCampaign::new(CampaignConfig {
            stride: 1,
            ..unbounded()
        });
        let k = KernelId(7);
        assert!(c.arm(k, Scheme::None, 64).is_none());
        assert_eq!(c.occurrences_of(k), 0,
                   "ineligible schemes must not consume occurrences");
        assert!(c.arm(k, Scheme::Dmr, 64).is_some());
        assert_eq!(c.occurrences_of(k), 1);
    }

    /// With an unbounded rate and stride 1, every eligible execution
    /// strikes, faults stay inside the output, and the magnitude range
    /// holds.
    #[test]
    fn campaign_faults_are_in_range() {
        let c = InjectionCampaign::new(CampaignConfig {
            stride: 1,
            ..unbounded()
        });
        for kid in 0..8u16 {
            for _ in 0..16 {
                let f = c.arm(KernelId(kid), Scheme::AbftFused, 13)
                    .expect("stride 1 + unbounded rate strikes always");
                assert!(f.i < 13 && f.j < 13);
                let mag = f.delta.abs();
                assert!((1e2..=1e6).contains(&mag), "delta={}", f.delta);
            }
        }
        assert_eq!(c.injected(), 8 * 16);
        assert_eq!(c.suppressed(), 0);
    }

    /// The token bucket caps the realized rate: a burst of candidates
    /// at t≈0 fires exactly the starting budget (1) and suppresses the
    /// rest instead of overshooting the target.
    #[test]
    fn campaign_rate_gate_suppresses_over_budget_candidates() {
        let c = InjectionCampaign::new(CampaignConfig {
            stride: 1,
            rate_per_min: 0.001, // ~one strike per 1000 minutes
            ..Default::default()
        });
        let mut armed = 0;
        for _ in 0..50 {
            if c.arm(KernelId(3), Scheme::Dmr, 32).is_some() {
                armed += 1;
            }
        }
        assert_eq!(armed, 1, "only the t=0 budget of one strike fires");
        assert_eq!(c.injected(), 1);
        assert_eq!(c.suppressed(), 49);
        assert_eq!(c.occurrences_of(KernelId(3)), 50,
                   "suppression still consumes the occurrence");
    }
}
