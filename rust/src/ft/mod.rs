//! The fault-tolerance engine (paper §4-§5).
//!
//! - [`dmr`] — duplicate-and-verify wrappers for the memory-bound
//!   Level-1/2 native routines (the paper's §4 scheme; the Pallas-side
//!   DMR lives inside the AOT kernels).
//! - [`abft`] — checksum-based online ABFT primitives for the
//!   compute-bound Level-3 routines: encode / verify / locate / correct,
//!   plus the unfused "ABFT-on-third-party" path the paper's Fig. 8
//!   compares against.
//! - [`abft_fused`] — the paper's §5.2 contribution: the native GEMM
//!   frame with every checksum access fused into the packing routines,
//!   the β-scaling pass, and the macro kernel's register tile. (The
//!   Pallas-side fused kernel is `python/compile/kernels/gemm_abft.py`.)
//! - [`injector`] — the deterministic fault-injection substrate standing
//!   in for physical transient faults (DESIGN.md substitution #3).
//! - [`policy`] — which protection scheme a request runs under.

pub mod abft;
pub mod abft_fused;
pub mod abft_weighted;
pub mod dmr;
pub mod injector;
pub mod policy;

/// Outcome counters a protected execution reports back to the metrics
/// layer (paper §6.3 validates against these).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FtReport {
    /// Faults the scheme detected.
    pub errors_detected: u64,
    /// Detected faults corrected in place.
    pub errors_corrected: u64,
}

impl FtReport {
    /// A clean report (no errors).
    pub fn none() -> Self {
        Self::default()
    }

    /// Accumulate another report's counters.
    pub fn merge(&mut self, other: FtReport) {
        self.errors_detected += other.errors_detected;
        self.errors_corrected += other.errors_corrected;
    }
}
