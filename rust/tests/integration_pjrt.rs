//! Integration tests over the PJRT artifact path: manifest loading,
//! artifact execution vs the native oracle, fused-ABFT online correction,
//! and the DMR kernels' error reporting.
//!
//! These tests require `make artifacts`; they skip (pass trivially) when
//! the manifest is absent so `cargo test` works on a fresh checkout.

use std::path::PathBuf;

use ftblas::blas::Impl;
use ftblas::config::Profile;
use ftblas::coordinator::executor::PjrtExecutor;
use ftblas::coordinator::pjrt_backend::PjrtBackend;
use ftblas::coordinator::plan::{Planner, SelectionPolicy};
use ftblas::coordinator::request::{Backend, BlasRequest, BlasResponse,
                                   BlasResult};
use ftblas::coordinator::router::{execute_plan, Router};
use ftblas::ft::injector::Fault;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::matrix::{allclose, Matrix};
use ftblas::util::rng::Rng;

/// Plan onto the pinned naive native ladder and run the plan — the
/// oracle the artifact results are compared against.
fn run_native(req: &BlasRequest, profile: &Profile) -> BlasResponse {
    let plan = Planner::new(profile)
        .plan(req, &SelectionPolicy::for_variant(Impl::Naive),
              FtPolicy::None)
        .expect("the naive ladder serves every routine");
    execute_plan(req, &plan, profile, None)
}

/// Plan under the router's PJRT-preferring selection and run the plan
/// (the artifact path when the loaded set serves the shape).
fn run_planned(router: &Router, req: &BlasRequest, policy: FtPolicy,
               fault: Option<Fault>) -> BlasResponse {
    let plan = router.plan(req, policy).expect("router always plans");
    router.execute_planned(&plan, req, fault)
        .expect("planned execution succeeds")
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Profile::skylake_sim().artifact_path();
    dir.join("manifest.tsv").exists().then_some(dir)
}

fn router() -> Option<Router> {
    let dir = artifacts_dir()?;
    let exec = PjrtExecutor::spawn(dir.clone()).ok()?;
    let pjrt = PjrtBackend::new(exec.handle.clone(), &dir).ok()?;
    std::mem::forget(exec); // keep the executor thread for the test binary
    Some(Router::with_pjrt(Profile::skylake_sim(), pjrt, Backend::Pjrt))
}

fn results_match(a: &BlasResult, b: &BlasResult, tol: f64) -> bool {
    match (a, b) {
        (BlasResult::Scalar(x), BlasResult::Scalar(y)) => {
            (x - y).abs() <= tol * (1.0 + y.abs())
        }
        (BlasResult::Vector(x), BlasResult::Vector(y)) => allclose(x, y, tol, tol),
        (BlasResult::Matrix(x), BlasResult::Matrix(y)) => {
            allclose(&x.data, &y.data, tol, tol)
        }
        _ => false,
    }
}

#[test]
fn manifest_covers_the_paper_routines() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let m = ftblas::runtime::manifest::Manifest::load(&dir).unwrap();
    for routine in ["dscal", "dnrm2", "dgemv", "dtrsv", "dgemm", "dsymm",
                    "dtrmm", "dtrsm"] {
        assert!(m.specs.iter().any(|s| s.routine == routine),
                "missing artifacts for {routine}");
    }
    // every FT variant carries an injection operand as its last input
    for s in &m.specs {
        if ["dmr", "abft", "abft_rankk", "ft"].contains(&s.variant.as_str()) {
            let last = s.inputs.last().unwrap();
            assert_eq!(last.rank(), 1, "{}", s.name);
            assert!((3..=5).contains(&last.0[0]), "{}", s.name);
        }
    }
}

#[test]
fn artifacts_match_native_oracle() {
    let Some(router) = router() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let profile = Profile::skylake_sim();
    let mut rng = Rng::new(0x77);
    let n = 256;
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let l = Matrix::random_lower_triangular(n, &mut rng);
    let reqs = vec![
        BlasRequest::Dscal { alpha: 2.25, x: rng.normal_vec(65536) },
        BlasRequest::Ddot { x: rng.normal_vec(65536), y: rng.normal_vec(65536) },
        BlasRequest::Dgemv { alpha: 1.5, a: a.clone(), x: rng.normal_vec(n),
                             beta: -0.5, y: rng.normal_vec(n) },
        BlasRequest::Dtrsv { a: l.clone(), b: rng.normal_vec(n) },
        BlasRequest::Dgemm { alpha: 1.0, a: a.clone(), b: b.clone(),
                             beta: 0.0, c: Matrix::zeros(n, n) },
        BlasRequest::Dtrsm { a: l.clone(), b: b.clone() },
    ];
    for req in reqs {
        let plan = router.plan(&req, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.backend, Backend::Pjrt,
                   "{} should plan onto the PJRT peer", req.routine());
        let want = run_native(&req, &profile);
        let got = router.execute_planned(&plan, &req, None).unwrap();
        assert!(results_match(&got.result, &want.result, 1e-6),
                "{} artifact diverges from the oracle", req.routine());
    }
}

#[test]
fn fused_abft_corrects_online() {
    let Some(router) = router() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let profile = Profile::skylake_sim();
    let mut rng = Rng::new(0x78);
    let n = 256; // has an abft_rankk artifact (kc=64): 4 online steps
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let req = BlasRequest::Dgemm {
        alpha: 1.0, a, b, beta: 0.0, c: Matrix::zeros(n, n),
    };
    let want = run_native(&req, &profile);
    for step in 0..4 {
        let fault = Fault { step, i: 11 + step, j: 200 - step, delta: 3e5 };
        let got = run_planned(&router, &req, FtPolicy::Hybrid, Some(fault));
        assert_eq!(got.ft.errors_detected, 1, "step {step}");
        assert_eq!(got.ft.errors_corrected, 1, "step {step}");
        assert!(results_match(&got.result, &want.result, 1e-6),
                "online correction failed at rank-k step {step}");
    }
}

#[test]
fn dmr_artifacts_report_and_correct() {
    let Some(router) = router() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let profile = Profile::skylake_sim();
    let mut rng = Rng::new(0x79);
    let x = rng.normal_vec(65536);
    let req = BlasRequest::Dscal { alpha: 3.5, x: x.clone() };
    let want = run_native(&req, &profile);
    let fault = Fault { step: 0, i: 12345, j: 0, delta: 7e6 };
    let got = run_planned(&router, &req, FtPolicy::Hybrid, Some(fault));
    assert_eq!(got.ft.errors_detected, 1);
    assert!(results_match(&got.result, &want.result, 1e-9));
}

#[test]
fn unfused_policy_on_pjrt() {
    let Some(router) = router() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let profile = Profile::skylake_sim();
    let mut rng = Rng::new(0x7A);
    let n = 256;
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let req = BlasRequest::Dgemm {
        alpha: 1.0, a, b, beta: 0.0, c: Matrix::zeros(n, n),
    };
    let want = run_native(&req, &profile);
    let fault = Fault { step: 0, i: 100, j: 50, delta: 9e4 };
    let got = run_planned(&router, &req, FtPolicy::AbftUnfused, Some(fault));
    assert_eq!(got.ft.errors_detected, 1);
    assert!(results_match(&got.result, &want.result, 1e-6));
}

#[test]
fn cascade_profile_artifacts() {
    let dir = Profile::cascade_sim().artifact_path();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let m = ftblas::runtime::manifest::Manifest::load(&dir).unwrap();
    assert_eq!(m.profile, "cascade_sim");
    assert!(m.find("dtrsv", "dmr").len() >= 1);
    assert!(m.find("dgemm", "abft").len() >= 1);
}
