//! Pooled execution must be indistinguishable from the `--no-pool`
//! scoped frames: every MT kernel and batched driver routed through the
//! persistent compute pool has to produce **bitwise** identical results
//! — and, on the fused-ABFT paths, exactly balanced per-band
//! detection/correction accounting — at random shapes, thread grants,
//! and pool sizes. The frames themselves are the variable under test:
//! each property runs the same call once with no pool installed (the
//! scoped fork/join fallback) and once under [`pool::enter`], then
//! compares outputs with `==`, not a tolerance.
//!
//! Uses the repo's seeded check harness (`util::check`) — proptest is
//! not vendored in this offline image; see DESIGN.md §9.

use std::sync::Arc;

use ftblas::blas::batched::{self, GemmItem};
use ftblas::blas::level3::GemmParams;
use ftblas::blas::parallel;
use ftblas::ft::abft_fused::Strike;
use ftblas::ft::FtReport;
use ftblas::runtime::pool::{self, ComputePool};
use ftblas::util::check::{check, ensure, Gen};
use ftblas::util::matrix::Matrix;
use ftblas::util::rng::Rng;

/// One batched item spec: (m, n, k, a, b, c0, strikes).
type BatchSpec =
    (usize, usize, usize, Vec<f64>, Vec<f64>, Vec<f64>, Vec<Strike>);

/// Outputs of one batched A/B run: scalar / simd / fused results plus
/// the fused driver's per-item reports.
type BatchOut =
    (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<FtReport>);

/// A pool sized from the case's RNG, so identity holds whether the pool
/// is under- or over-provisioned relative to the thread grant.
fn random_pool(rng: &mut Rng) -> Arc<ComputePool> {
    Arc::new(ComputePool::new(1 + rng.below(6)))
}

#[test]
fn pooled_dgemm_mt_is_bitwise_scoped() {
    check("pool-gemm-identity", 10, |g| {
        let m = g.dim(17, 140); // above the band floor for both MRs
        let n = g.dim(1, 80);
        let k = g.dim(1, 60);
        let threads = 2 + g.rng.below(4);
        let params = GemmParams::default();
        let a = Matrix::random(m, k, &mut g.rng);
        let b = Matrix::random(k, n, &mut g.rng);
        let c0 = Matrix::random(m, n, &mut g.rng);
        // scoped baseline: no pool installed on this thread
        assert!(pool::current().is_none());
        let mut scoped = c0.data.clone();
        parallel::dgemm_mt(m, n, k, 0.9, &a.data, &b.data, -0.3,
                           &mut scoped, &params, threads);
        let mut scoped_simd = c0.data.clone();
        parallel::dgemm_simd_mt(m, n, k, 0.9, &a.data, &b.data, -0.3,
                                &mut scoped_simd, &params, threads);
        // pooled run: identical calls under an installed pool
        let compute = random_pool(&mut g.rng);
        let mut pooled = c0.data.clone();
        let mut pooled_simd = c0.data.clone();
        {
            let _guard = pool::enter(compute.clone());
            parallel::dgemm_mt(m, n, k, 0.9, &a.data, &b.data, -0.3,
                               &mut pooled, &params, threads);
            parallel::dgemm_simd_mt(m, n, k, 0.9, &a.data, &b.data, -0.3,
                                    &mut pooled_simd, &params, threads);
        }
        ensure(pooled == scoped,
               format!("pooled dgemm_mt diverged bitwise (t={threads})"))?;
        ensure(pooled_simd == scoped_simd,
               format!("pooled dgemm_simd_mt diverged bitwise (t={threads})"))?;
        let stats = compute.stats();
        ensure(stats.tasks_submitted > 0, "frames bypassed the pool")?;
        ensure(stats.tasks_executed == stats.tasks_submitted,
               format!("pool leaked tasks: {} submitted, {} executed",
                       stats.tasks_submitted, stats.tasks_executed))
    });
}

#[test]
fn pooled_level3_variants_are_bitwise_scoped() {
    check("pool-l3-identity", 8, |g| {
        let m = g.dim(17, 120);
        let n = g.dim(2, 64);
        let threads = 2 + g.rng.below(4);
        let params = GemmParams::default();
        let sym = Matrix::random_symmetric(m, &mut g.rng);
        let tri = Matrix::random_lower_triangular(m, &mut g.rng);
        let b0 = Matrix::random(m, n, &mut g.rng);
        let c0 = Matrix::random(m, n, &mut g.rng);
        // scoped baselines
        let mut symm_s = c0.data.clone();
        parallel::dsymm_lower_mt(m, n, 1.3, &sym.data, &b0.data, -0.6,
                                 &mut symm_s, &params, threads);
        let mut trmm_s = b0.data.clone();
        parallel::dtrmm_lower_mt(m, n, 0.8, &tri.data, &mut trmm_s,
                                 &params, threads);
        let mut trsm_s = b0.data.clone();
        parallel::dtrsm_llnn_mt(m, n, &tri.data, &mut trsm_s, 32, &params,
                                threads);
        // pooled runs
        let compute = random_pool(&mut g.rng);
        let mut symm_p = c0.data.clone();
        let mut trmm_p = b0.data.clone();
        let mut trsm_p = b0.data.clone();
        {
            let _guard = pool::enter(compute.clone());
            parallel::dsymm_lower_mt(m, n, 1.3, &sym.data, &b0.data, -0.6,
                                     &mut symm_p, &params, threads);
            parallel::dtrmm_lower_mt(m, n, 0.8, &tri.data, &mut trmm_p,
                                     &params, threads);
            parallel::dtrsm_llnn_mt(m, n, &tri.data, &mut trsm_p, 32,
                                    &params, threads);
        }
        ensure(symm_p == symm_s, "pooled dsymm_lower_mt diverged bitwise")?;
        ensure(trmm_p == trmm_s, "pooled dtrmm_lower_mt diverged bitwise")?;
        ensure(trsm_p == trsm_s, "pooled dtrsm_llnn_mt diverged bitwise")?;
        let stats = compute.stats();
        ensure(stats.tasks_executed == stats.tasks_submitted,
               "pool leaked tasks across level-3 variants")
    });
}

/// Fused-ABFT MT frames under campaign-armed strikes: the pooled run
/// must reproduce the scoped run's corrected output bitwise AND its
/// merged [`FtReport`] exactly — per-band detection/correction counts
/// balance no matter which pool worker executed which band.
#[test]
fn pooled_fused_mt_strike_accounting_balances() {
    check("pool-fused-identity", 8, |g| {
        let m = g.dim(17, 110);
        let n = g.dim(4, 64);
        let k = g.dim(8, 64);
        let threads = 2 + g.rng.below(4);
        let params = GemmParams { kc: 16, ..Default::default() };
        let a = Matrix::random(m, k, &mut g.rng);
        let b = Matrix::random(k, n, &mut g.rng);
        let steps = k.div_ceil(params.kc);
        let strikes: Vec<Strike> = (0..1 + g.rng.below(3))
            .map(|_| (g.rng.below(steps), g.rng.below(m), g.rng.below(n),
                      2e4 + g.rng.uniform() * 8e4))
            .collect();
        assert!(pool::current().is_none());
        let mut scoped = vec![0.0; m * n];
        let rep_scoped = parallel::dgemm_abft_fused_mt(
            m, n, k, 1.0, &a.data, &b.data, 0.0, &mut scoped, &params,
            threads, &strikes);
        let mut scoped_simd = vec![0.0; m * n];
        let rep_scoped_simd = parallel::dgemm_abft_fused_simd_mt(
            m, n, k, 1.0, &a.data, &b.data, 0.0, &mut scoped_simd, &params,
            threads, &strikes);
        let compute = random_pool(&mut g.rng);
        let mut pooled = vec![0.0; m * n];
        let mut pooled_simd = vec![0.0; m * n];
        let (rep_pooled, rep_pooled_simd) = {
            let _guard = pool::enter(compute.clone());
            (parallel::dgemm_abft_fused_mt(
                 m, n, k, 1.0, &a.data, &b.data, 0.0, &mut pooled, &params,
                 threads, &strikes),
             parallel::dgemm_abft_fused_simd_mt(
                 m, n, k, 1.0, &a.data, &b.data, 0.0, &mut pooled_simd,
                 &params, threads, &strikes))
        };
        ensure(pooled == scoped, "pooled fused mt diverged bitwise")?;
        ensure(pooled_simd == scoped_simd,
               "pooled fused simd mt diverged bitwise")?;
        ensure(rep_pooled == rep_scoped,
               format!("fused mt reports diverged: pooled {rep_pooled:?} \
                        vs scoped {rep_scoped:?}"))?;
        ensure(rep_pooled_simd == rep_scoped_simd,
               format!("fused simd mt reports diverged: pooled \
                        {rep_pooled_simd:?} vs scoped {rep_scoped_simd:?}"))?;
        let stats = compute.stats();
        ensure(stats.tasks_executed == stats.tasks_submitted,
               "pool leaked tasks on the fused paths")
    });
}

#[test]
fn pooled_batched_drivers_are_bitwise_scoped() {
    check("pool-batched-identity", 8, |g| {
        let count = 3 + g.rng.below(4);
        let threads = 2 + g.rng.below(3);
        let params = GemmParams { kc: 16, ..Default::default() };
        // shapes straddling the banding floor, strikes on every other item
        let specs: Vec<BatchSpec> = (0..count)
            .map(|i| {
                let m = 3 + g.rng.below(44);
                let n = 2 + g.rng.below(24);
                let k = 8 + g.rng.below(24);
                let a = Matrix::random(m, k, &mut g.rng).data;
                let b = Matrix::random(k, n, &mut g.rng).data;
                let c = Matrix::random(m, n, &mut g.rng).data;
                let inject = if i % 2 == 0 {
                    vec![(0, g.rng.below(m), g.rng.below(n), 5e4)]
                } else {
                    Vec::new()
                };
                (m, n, k, a, b, c, inject)
            })
            .collect();
        let run = |pooled: bool, g: &mut Gen| -> BatchOut {
            let _guard = pooled.then(|| pool::enter(random_pool(&mut g.rng)));
            let mut scalar: Vec<Vec<f64>> =
                specs.iter().map(|s| s.5.clone()).collect();
            let mut items: Vec<GemmItem<'_>> = specs
                .iter()
                .zip(scalar.iter_mut())
                .map(|(s, c)| GemmItem {
                    m: s.0, n: s.1, k: s.2, alpha: 0.7, beta: -0.4,
                    a: &s.3[..], b: &s.4[..], c: &mut c[..],
                    inject: Vec::new(),
                })
                .collect();
            batched::dgemm_batched(&mut items, &params, threads);
            drop(items);
            let mut simd: Vec<Vec<f64>> =
                specs.iter().map(|s| s.5.clone()).collect();
            let mut items: Vec<GemmItem<'_>> = specs
                .iter()
                .zip(simd.iter_mut())
                .map(|(s, c)| GemmItem {
                    m: s.0, n: s.1, k: s.2, alpha: 0.7, beta: -0.4,
                    a: &s.3[..], b: &s.4[..], c: &mut c[..],
                    inject: Vec::new(),
                })
                .collect();
            batched::dgemm_batched_simd(&mut items, &params, threads);
            drop(items);
            let mut fused: Vec<Vec<f64>> =
                specs.iter().map(|s| vec![0.0; s.0 * s.1]).collect();
            let mut items: Vec<GemmItem<'_>> = specs
                .iter()
                .zip(fused.iter_mut())
                .map(|(s, c)| GemmItem {
                    m: s.0, n: s.1, k: s.2, alpha: 1.0, beta: 0.0,
                    a: &s.3[..], b: &s.4[..], c: &mut c[..],
                    inject: s.6.clone(),
                })
                .collect();
            let reps = batched::dgemm_batched_abft_fused_simd(
                &mut items, &params, threads);
            drop(items);
            (scalar, simd, fused, reps)
        };
        assert!(pool::current().is_none());
        let (scalar_s, simd_s, fused_s, reps_s) = run(false, g);
        let (scalar_p, simd_p, fused_p, reps_p) = run(true, g);
        ensure(scalar_p == scalar_s,
               "pooled batched scalar diverged bitwise")?;
        ensure(simd_p == simd_s, "pooled batched simd diverged bitwise")?;
        ensure(fused_p == fused_s, "pooled batched fused diverged bitwise")?;
        ensure(reps_p == reps_s,
               format!("per-item reports diverged: pooled {reps_p:?} vs \
                        scoped {reps_s:?}"))
    });
}

/// The long-lived pool a serving cluster would own: many frames reuse
/// one pool, and after an explicit shutdown (the `Drop`/join guarantee)
/// every submitted task has executed — the soak gate's no-leak
/// invariant, pinned here at the unit scale.
#[test]
fn one_pool_survives_many_frames_and_drains_on_shutdown() {
    let mut rng = Rng::new(0xB00F5);
    let compute = Arc::new(ComputePool::new(3));
    let params = GemmParams::default();
    for round in 0..6 {
        let m = 32 + 8 * round;
        let (n, k) = (24, 16);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let c0 = Matrix::random(m, n, &mut rng);
        let mut scoped = c0.data.clone();
        parallel::dgemm_mt(m, n, k, 1.1, &a.data, &b.data, 0.2, &mut scoped,
                           &params, 4);
        let mut pooled = c0.data.clone();
        {
            let _guard = pool::enter(compute.clone());
            parallel::dgemm_mt(m, n, k, 1.1, &a.data, &b.data, 0.2,
                               &mut pooled, &params, 4);
        }
        assert_eq!(pooled, scoped, "round {round} diverged bitwise");
    }
    let before = compute.stats();
    assert!(before.tasks_submitted > 0, "frames never reached the pool");
    assert_eq!(before.workers, 3, "no per-frame worker spawns");
    compute.shutdown();
    let after = compute.stats();
    assert_eq!(after.tasks_executed, after.tasks_submitted,
               "shutdown leaked queued tasks");
}
