//! Property tests on the capability-selection layer (the PR-10 API):
//!
//! - tightening a [`SelectionPolicy`] (adding a denial, a capability
//!   requirement, or an allowlist) never turns an unplannable key
//!   plannable, and whatever it selects satisfies every added
//!   constraint — candidate filtering is monotone;
//! - selection is deterministic: fresh planners and the plan cache
//!   agree on every `(routine, dim, policy, selection)` key, including
//!   keys with denials and requirements;
//! - a failed selection accounts for every registered descriptor of
//!   the routine, each with a concrete miss reason;
//! - pin-compat regression: under the default `--variant` selections
//!   the planner reproduces the pre-redesign three-rung ladder
//!   bit-identically (same kernel, same thread grant) across
//!   routines × dims × policies × variants × thread counts × profiles.
//!
//! Uses the repo's seeded check harness (`util::check`) — proptest is
//! not vendored in this offline image; see DESIGN.md §9.

use ftblas::blas::Impl;
use ftblas::config::Profile;
use ftblas::coordinator::plan::{CapRequirement, PlanCache, Planner,
                                SelectionPolicy};
use ftblas::coordinator::registry::KernelRegistry;
use ftblas::coordinator::request::Backend;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::check::{check, ensure};
use ftblas::util::rng::Rng;

/// A random selection policy: an ordered duplicate-free preference
/// list plus (rarely) an allowlist, denials, and requirements drawn
/// from the parseable `cap=value` vocabulary.
fn random_selection(rng: &mut Rng) -> SelectionPolicy {
    let mut sel = SelectionPolicy::default();
    for _ in 0..rng.below(4) {
        let be = Backend::ALL[rng.below(Backend::ALL.len())];
        if !sel.prefer.contains(&be) {
            sel.prefer.push(be);
        }
    }
    if rng.below(4) == 0 {
        for _ in 0..1 + rng.below(3) {
            let be = Backend::ALL[rng.below(Backend::ALL.len())];
            if !sel.allow.contains(&be) {
                sel.allow.push(be);
            }
        }
    }
    if rng.below(3) == 0 {
        sel = sel.with_denied(Backend::ALL[rng.below(Backend::ALL.len())]);
    }
    if rng.below(3) == 0 {
        sel.require.push(random_requirement(rng));
    }
    sel
}

/// One requirement from the `--require` vocabulary, all satisfiable by
/// at least some registered descriptor.
fn random_requirement(rng: &mut Rng) -> CapRequirement {
    let pool = [("precision", "f64"), ("scheme", "none"),
                ("scheme", "abft-fused"), ("scheme", "dmr"),
                ("threaded", "true"), ("threaded", "false"),
                ("batched", "true"), ("batched", "false"),
                ("feature", "avx2"), ("feature", "fma")];
    let (k, v) = pool[rng.below(pool.len())];
    CapRequirement::parse(k, v).expect("pool entries parse")
}

/// Tighten `sel` by one random move: an extra denial, an extra
/// requirement, or a shrunk allowlist. Every move can only remove
/// candidates, never add them.
fn tighten(mut sel: SelectionPolicy, rng: &mut Rng) -> SelectionPolicy {
    match rng.below(3) {
        0 => sel.with_denied(Backend::ALL[rng.below(Backend::ALL.len())]),
        1 => {
            sel.require.push(random_requirement(rng));
            sel
        }
        _ => {
            let universe: Vec<Backend> = if sel.allow.is_empty() {
                Backend::ALL.to_vec()
            } else {
                sel.allow.clone()
            };
            sel.allow = universe
                .into_iter()
                .filter(|_| rng.below(2) == 0)
                .collect();
            if sel.allow.is_empty() {
                // an empty allowlist means "everything": keep one entry
                // so the move stays a strict-or-equal tightening
                sel.allow.push(Backend::ALL[rng.below(Backend::ALL.len())]);
            }
            sel
        }
    }
}

/// Tightening a selection never turns a failing key into a success,
/// and whatever the tightened selection picks satisfies every one of
/// its constraints.
#[test]
fn constraints_only_shrink_the_candidate_set() {
    let reg = KernelRegistry::global();
    check("selection-monotone", 80, |g| {
        let routines = reg.routines();
        let routine = routines[g.rng.below(routines.len())];
        let dim = 4 + g.rng.below(192);
        let policy = FtPolicy::ALL[g.rng.below(FtPolicy::ALL.len())];
        let profile = Profile::default().with_threads(1 + g.rng.below(8));
        let planner = Planner::new(&profile);
        let base = random_selection(&mut g.rng);
        let tight_sel = tighten(base.clone(), &mut g.rng);
        let loose = planner.plan_dims(routine, dim, &base, policy);
        let tight = planner.plan_dims(routine, dim, &tight_sel, policy);
        let Some(t) = tight else { return Ok(()) };
        ensure(loose.is_some(),
               format!("{routine}/{dim}: tightening revived a dead key"))?;
        let caps = t.kernel.capabilities();
        for r in &tight_sel.require {
            ensure(r.satisfied_by(&caps),
                   format!("{} violates required {}", t.kernel.name,
                           r.describe()))?;
        }
        ensure(!tight_sel.deny.contains(&t.kernel.backend),
               format!("{} planned from a denied backend", t.kernel.name))?;
        if !tight_sel.allow.is_empty() {
            ensure(tight_sel.allow.contains(&t.kernel.backend),
                   format!("{} planned from outside the allowlist",
                           t.kernel.name))?;
        }
        Ok(())
    });
}

/// Selection is a pure function of `(routine, dim, policy, selection,
/// profile)`: fresh planners agree with each other and with the plan
/// cache, on successes and on failures alike.
#[test]
fn selection_is_deterministic() {
    let reg = KernelRegistry::global();
    check("selection-deterministic", 80, |g| {
        let routines = reg.routines();
        let routine = routines[g.rng.below(routines.len())];
        let dim = 4 + g.rng.below(192);
        let policy = FtPolicy::ALL[g.rng.below(FtPolicy::ALL.len())];
        let profile = Profile::default().with_threads(1 + g.rng.below(8));
        let sel = random_selection(&mut g.rng);
        let a = Planner::new(&profile).select_dims(routine, dim, &sel, policy);
        let b = Planner::new(&profile).select_dims(routine, dim, &sel, policy);
        match (&a, &b) {
            (Ok(x), Ok(y)) => {
                ensure(x.kernel_id == y.kernel_id,
                       format!("{routine}/{dim}: {} vs {}", x.kernel.name,
                               y.kernel.name))?;
                ensure(x.threads == y.threads, "thread grant flapped")?;
            }
            (Err(x), Err(y)) => {
                ensure(x.considered == y.considered,
                       "diagnostic considered-count flapped")?;
                ensure(x.misses.len() == y.misses.len(),
                       "diagnostic miss-count flapped")?;
            }
            _ => return Err(format!("{routine}/{dim}: plannability flapped")),
        }
        let cache = PlanCache::new(profile.clone());
        let cached = cache.resolve(routine, dim, policy, &sel);
        ensure(cached.map(|p| (p.kernel_id, p.threads))
                   == a.ok().map(|p| (p.kernel_id, p.threads)),
               format!("{routine}/{dim}: cache disagrees with the planner"))
    });
}

/// When nothing qualifies, the [`NoCandidate`] diagnostic names every
/// registered descriptor of the routine with a concrete miss reason —
/// the gateway's 400 mapping depends on this being exhaustive.
#[test]
fn failed_selection_accounts_for_every_descriptor() {
    let reg = KernelRegistry::global();
    check("no-candidate-exhaustive", 40, |g| {
        let routines = reg.routines();
        let routine = routines[g.rng.below(routines.len())];
        let dim = 4 + g.rng.below(192);
        let policy = FtPolicy::ALL[g.rng.below(FtPolicy::ALL.len())];
        let profile = Profile::default().with_threads(1 + g.rng.below(8));
        // no registered kernel advertises avx512: selection must fail
        let mut sel = random_selection(&mut g.rng);
        sel.require.push(CapRequirement::parse("feature", "avx512").unwrap());
        let err = Planner::new(&profile)
            .select_dims(routine, dim, &sel, policy)
            .expect_err("an unsatisfiable requirement must not plan");
        ensure(err.considered == reg.for_routine(routine).len(),
               format!("{routine}: considered {} of {}", err.considered,
                       reg.for_routine(routine).len()))?;
        ensure(err.misses.len() == err.considered,
               "every considered descriptor needs a miss entry")?;
        for m in &err.misses {
            ensure(!m.missing.is_empty(),
                   format!("{}: miss entry without a reason", m.name))?;
        }
        let text = err.to_string();
        ensure(text.contains(routine),
               "diagnostic must name the routine")?;
        ensure(text.contains("lacks required feature=avx512"),
               "diagnostic must name the unsatisfiable requirement")
    });
}

/// Pin-compat regression: the pre-redesign planner walked a three-rung
/// ladder over the native registry — (1) a threaded kernel of the
/// requested variant above its MR floor when the profile grants
/// threads, (2) a serial kernel of the variant, (3) any serial kernel
/// in registration order. Under the `--variant` selections the
/// capability planner must reproduce that ladder bit-identically; when
/// the ladder comes up empty, anything the new planner finds must come
/// from a peer backend the old registry did not hold.
#[test]
fn default_profile_plans_match_the_legacy_ladder() {
    fn legacy_ladder(routine: &str, dim: usize, variant: Impl,
                     profile: &Profile, policy: FtPolicy)
                     -> Option<(&'static str, usize)> {
        let mr = profile.gemm.mr;
        let threads = profile.threads.max(1);
        let be = Backend::for_variant(variant);
        let candidates: Vec<_> = KernelRegistry::global()
            .for_routine(routine)
            .into_iter()
            .filter(|k| k.backend.is_native() && k.supports(policy)
                        && k.serves_dim(dim))
            .collect();
        if threads > 1 {
            if let Some(k) = candidates.iter().find(|k| {
                k.threaded && k.backend == be && k.admits_dim(dim, mr)
            }) {
                return Some((k.name, threads));
            }
        }
        if let Some(k) =
            candidates.iter().find(|k| !k.threaded && k.backend == be)
        {
            return Some((k.name, 1));
        }
        candidates.iter().find(|k| !k.threaded).map(|k| (k.name, 1))
    }

    let reg = KernelRegistry::global();
    let mut checked = 0u64;
    for base in [Profile::skylake_sim(), Profile::cascade_sim()] {
        for threads in [1usize, 4] {
            let profile = base.clone().with_threads(threads);
            let planner = Planner::new(&profile);
            for routine in reg.routines() {
                for dim in [4usize, 8, 24, 48, 64, 96, 160] {
                    for policy in FtPolicy::ALL {
                        for variant in Impl::ALL {
                            let want = legacy_ladder(routine, dim, variant,
                                                     &profile, policy);
                            let sel = SelectionPolicy::for_variant(variant);
                            let got = planner
                                .plan_dims(routine, dim, &sel, policy)
                                .map(|p| (p.kernel.name, p.threads,
                                          p.kernel.backend));
                            match (want, got) {
                                (Some((name, t)), got) => {
                                    let be = reg.find(name).unwrap().backend;
                                    assert_eq!(
                                        got, Some((name, t, be)),
                                        "{routine}/{dim} {policy:?} \
                                         {variant:?} t={threads}: ladder \
                                         drifted");
                                    checked += 1;
                                }
                                (None, Some((name, _, backend))) => {
                                    assert!(!backend.is_native(),
                                            "{routine}/{dim} {policy:?}: new \
                                             native plan {name} where the \
                                             legacy ladder had none");
                                }
                                (None, None) => {}
                            }
                        }
                    }
                }
            }
        }
    }
    assert!(checked > 2_000,
            "pin-compat sweep degenerated: only {checked} ladder matches");
}
