//! Integration tests over the threaded coordinator: concurrency,
//! batching fairness, metrics accounting, end-to-end injection through
//! the server loop, and the plan-aware pipeline (admission-time
//! planning, kernel-keyed batching, the thread-budget ledger).

use ftblas::config::Profile;
use ftblas::coordinator::request::{Backend, BlasRequest};
use ftblas::coordinator::router::Router;
use ftblas::coordinator::server::Server;
use ftblas::coordinator::trace::{self, TraceConfig};
use ftblas::ft::injector::InjectorConfig;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::matrix::Matrix;
use ftblas::util::rng::Rng;

fn native_server(policy: FtPolicy, workers: usize,
                 inj: Option<InjectorConfig>, expected: usize) -> Server {
    let router = Router::native_only(Profile::default(), Backend::NativeTuned);
    Server::start(router, policy, workers, inj, expected)
}

#[test]
fn high_concurrency_mixed_trace() {
    let cfg = TraceConfig {
        requests: 120,
        vec_len: 4096,
        mat_dim: 64,
        ..Default::default()
    };
    let entries = trace::generate(&cfg);
    let server = native_server(FtPolicy::None, 6, None, entries.len());
    let handle = server.handle();
    let rxs: Vec<_> = entries
        .iter()
        .map(|e| handle.submit(e.request.clone()))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 120);
    assert_eq!(m.failed, 0);
    // every routine in the mix got latency records
    assert!(m.e2e_by_routine.len() >= 4);
    // the per-kernel ledger names the executed kernels and its
    // completion counts roll up exactly
    let ledger_total: u64 = m.kernels.values().map(|k| k.completed).sum();
    assert_eq!(ledger_total, 120);
    assert!(m.kernels.keys().all(|k| k.contains('/')),
            "ledger keys are registry kernel names: {:?}", m.kernels.keys());
    // admission planned every native request exactly once per shape
    assert_eq!(m.plan_cache_hits + m.plan_cache_misses, 120);
    assert!(m.plan_cache_hits > m.plan_cache_misses);
}

/// The oversubscription gate: on a cascade_sim-style profile with a
/// constrained thread budget, eligible DGEMMs ride the MT kernel while
/// the in-flight thread ledger never exceeds the budget.
#[test]
fn mt_dgemm_respects_thread_budget() {
    // cascade grants 4 kernel threads; budget 6 admits one MT batch
    // plus serial traffic, never two MT batches at once
    let profile = Profile::cascade_sim().with_thread_budget(6).with_max_batch(2);
    let workers = 3;
    let router = Router::native_only(profile, Backend::NativeTuned);
    let server = Server::start(router, FtPolicy::None, workers, None, 0);
    let handle = server.handle();
    let mut rng = Rng::new(0x0B5);
    let a = Matrix::random(96, 96, &mut rng);
    let b = Matrix::random(96, 96, &mut rng);
    let mut rxs = Vec::new();
    for i in 0..24 {
        let rx = if i % 2 == 0 {
            handle.submit(BlasRequest::Dgemm {
                alpha: 1.0,
                a: a.clone(),
                b: b.clone(),
                beta: 0.0,
                c: Matrix::zeros(96, 96),
            })
        } else {
            handle.submit(BlasRequest::Ddot {
                x: rng.normal_vec(4096),
                y: rng.normal_vec(4096),
            })
        };
        rxs.push((i % 2 == 0, rx));
    }
    for (is_gemm, rx) in rxs {
        let resp = rx.recv().unwrap().unwrap();
        if is_gemm {
            assert_eq!(resp.kernel, "dgemm/tuned-mt",
                       "eligible DGEMM must ride the MT kernel");
        }
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 24);
    assert_eq!(m.failed, 0);
    assert_eq!(m.thread_budget, 6);
    assert!(m.max_in_flight_threads >= 4,
            "an MT batch was admitted (max in-flight {})",
            m.max_in_flight_threads);
    assert!(m.max_in_flight_threads <= m.thread_budget,
            "thread ledger oversubscribed: {} > {}",
            m.max_in_flight_threads, m.thread_budget);
    // the ledger attributes completions to the executed kernels
    assert_eq!(m.kernels["dgemm/tuned-mt"].completed, 12);
    assert_eq!(m.kernels["ddot/tuned"].completed, 12);
    // two distinct admission keys, planned once each
    assert_eq!(m.plan_cache_misses, 2);
    assert_eq!(m.plan_cache_hits, 22);
}

/// Kernel-keyed batching: two DGEMM shapes whose plans resolve to the
/// same kernel land in one ledger entry (and one batch group), while a
/// shape planning to a different kernel stays separate.
#[test]
fn shapes_sharing_a_plan_share_a_ledger_entry() {
    let router = Router::native_only(Profile::default(), Backend::NativeTuned);
    let server = Server::start(router, FtPolicy::Hybrid, 2, None, 0);
    let handle = server.handle();
    let mut rng = Rng::new(0x51A);
    let mut submit_gemm = |n: usize| {
        handle.submit(BlasRequest::Dgemm {
            alpha: 1.0,
            a: Matrix::random(n, n, &mut rng),
            b: Matrix::random(n, n, &mut rng),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        })
    };
    let mut rxs = Vec::new();
    for _ in 0..4 {
        rxs.push(submit_gemm(48)); // serial fused-ABFT kernel
        rxs.push(submit_gemm(64)); // same plan, different shape
    }
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.kernel, "dgemm/abft-fused");
    }
    let m = server.shutdown();
    // one ledger entry absorbs both shapes
    assert_eq!(m.kernels["dgemm/abft-fused"].completed, 8);
    assert_eq!(m.kernels.len(), 1);
    // two shapes -> two plan-cache keys, each planned once
    assert_eq!(m.plan_cache_misses, 2);
    assert_eq!(m.plan_cache_hits, 6);
}

#[test]
fn metrics_account_for_every_injection() {
    let cfg = InjectorConfig { count: 10, ..Default::default() };
    let server = native_server(FtPolicy::Hybrid, 4, Some(cfg), 40);
    let handle = server.handle();
    let mut rng = Rng::new(3);
    let rxs: Vec<_> = (0..40)
        .map(|_| {
            handle.submit(BlasRequest::Dscal {
                alpha: 1.25,
                x: rng.normal_vec(2048),
            })
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 40);
    assert_eq!(m.errors_injected, 10);
    assert_eq!(m.errors_detected, 10);
    assert_eq!(m.errors_corrected, 10);
}

#[test]
fn call_is_synchronous_sugar() {
    let server = native_server(FtPolicy::None, 2, None, 4);
    let handle = server.handle();
    let resp = handle
        .call(BlasRequest::Ddot { x: vec![1.0, 2.0, 3.0, 4.0],
                                  y: vec![1.0; 4] })
        .unwrap();
    assert_eq!(resp.result.as_scalar().unwrap(), 10.0);
}

#[test]
fn unprotected_server_does_not_report_errors() {
    let server = native_server(FtPolicy::None, 2, None, 8);
    let handle = server.handle();
    let mut rng = Rng::new(9);
    for _ in 0..8 {
        handle
            .call(BlasRequest::Dnrm2 { x: rng.normal_vec(1024) })
            .unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.errors_detected, 0);
    assert_eq!(m.errors_injected, 0);
}
