//! Integration tests over the threaded coordinator: concurrency,
//! batching fairness, metrics accounting, and end-to-end injection
//! through the server loop.

use ftblas::config::Profile;
use ftblas::coordinator::request::{Backend, BlasRequest};
use ftblas::coordinator::router::Router;
use ftblas::coordinator::server::Server;
use ftblas::coordinator::trace::{self, TraceConfig};
use ftblas::ft::injector::InjectorConfig;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::rng::Rng;

fn native_server(policy: FtPolicy, workers: usize,
                 inj: Option<InjectorConfig>, expected: usize) -> Server {
    let router = Router::native_only(Profile::default(), Backend::NativeTuned);
    Server::start(router, policy, workers, inj, expected)
}

#[test]
fn high_concurrency_mixed_trace() {
    let cfg = TraceConfig {
        requests: 120,
        vec_len: 4096,
        mat_dim: 64,
        ..Default::default()
    };
    let entries = trace::generate(&cfg);
    let server = native_server(FtPolicy::None, 6, None, entries.len());
    let handle = server.handle();
    let rxs: Vec<_> = entries
        .iter()
        .map(|e| handle.submit(e.request.clone()))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 120);
    assert_eq!(m.failed, 0);
    // every routine in the mix got latency records
    assert!(m.e2e_by_routine.len() >= 4);
}

#[test]
fn metrics_account_for_every_injection() {
    let cfg = InjectorConfig { count: 10, ..Default::default() };
    let server = native_server(FtPolicy::Hybrid, 4, Some(cfg), 40);
    let handle = server.handle();
    let mut rng = Rng::new(3);
    let rxs: Vec<_> = (0..40)
        .map(|_| {
            handle.submit(BlasRequest::Dscal {
                alpha: 1.25,
                x: rng.normal_vec(2048),
            })
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 40);
    assert_eq!(m.errors_injected, 10);
    assert_eq!(m.errors_detected, 10);
    assert_eq!(m.errors_corrected, 10);
}

#[test]
fn call_is_synchronous_sugar() {
    let server = native_server(FtPolicy::None, 2, None, 4);
    let handle = server.handle();
    let resp = handle
        .call(BlasRequest::Ddot { x: vec![1.0, 2.0, 3.0, 4.0],
                                  y: vec![1.0; 4] })
        .unwrap();
    assert_eq!(resp.result.as_scalar().unwrap(), 10.0);
}

#[test]
fn unprotected_server_does_not_report_errors() {
    let server = native_server(FtPolicy::None, 2, None, 8);
    let handle = server.handle();
    let mut rng = Rng::new(9);
    for _ in 0..8 {
        handle
            .call(BlasRequest::Dnrm2 { x: rng.normal_vec(1024) })
            .unwrap();
    }
    let m = server.shutdown();
    assert_eq!(m.errors_detected, 0);
    assert_eq!(m.errors_injected, 0);
}
