//! Integration tests over the native path: router x policy x backend
//! matrix, FT invariants under randomized injection, and the Cholesky
//! downstream consumer.

use ftblas::blas::Impl;
use ftblas::config::Profile;
use ftblas::coordinator::plan::{Planner, SelectionPolicy};
use ftblas::coordinator::request::{BlasRequest, BlasResponse, BlasResult};
use ftblas::coordinator::router::execute_plan;
use ftblas::ft::injector::{Fault, Injector, InjectorConfig};
use ftblas::ft::policy::FtPolicy;
use ftblas::util::check::{check, ensure};
use ftblas::util::matrix::{allclose, Matrix};
use ftblas::util::rng::Rng;

/// Plan onto a pinned native variant and run the plan — every direct
/// execution in this suite goes through the planned path.
fn run_native(req: &BlasRequest, variant: Impl, profile: &Profile,
              policy: FtPolicy, fault: Option<Fault>) -> BlasResponse {
    let plan = Planner::new(profile)
        .plan(req, &SelectionPolicy::for_variant(variant), policy)
        .expect("the native ladder serves every routine");
    execute_plan(req, &plan, profile, fault)
}

fn results_match(a: &BlasResult, b: &BlasResult, tol: f64) -> bool {
    match (a, b) {
        (BlasResult::Scalar(x), BlasResult::Scalar(y)) => {
            (x - y).abs() <= tol * (1.0 + y.abs())
        }
        (BlasResult::Vector(x), BlasResult::Vector(y)) => allclose(x, y, tol, tol),
        (BlasResult::Matrix(x), BlasResult::Matrix(y)) => {
            allclose(&x.data, &y.data, tol, tol)
        }
        _ => false,
    }
}

/// The paper's central FT claim, as a property over all protected
/// routines: for ANY single fault (position x magnitude x step), the
/// protected run detects it and returns the fault-free answer.
#[test]
fn any_single_fault_is_transparent() {
    let profile = Profile::default();
    check("e2e-single-fault", 25, |g| {
        let n = 64 + 32 * g.rng.below(3);
        let a = Matrix::random(n, n, &mut g.rng);
        let b = Matrix::random(n, n, &mut g.rng);
        let l = Matrix::random_lower_triangular(n, &mut g.rng);
        let reqs = vec![
            BlasRequest::Dscal { alpha: 1.5, x: g.rng.normal_vec(n * 8) },
            BlasRequest::Ddot { x: g.rng.normal_vec(n * 8),
                                y: g.rng.normal_vec(n * 8) },
            BlasRequest::Dgemv { alpha: 1.0, a: a.clone(),
                                 x: g.rng.normal_vec(n), beta: 0.5,
                                 y: g.rng.normal_vec(n) },
            BlasRequest::Dtrsv { a: l.clone(), b: g.rng.normal_vec(n) },
            BlasRequest::Dgemm { alpha: 1.0, a: a.clone(), b: b.clone(),
                                 beta: 0.0, c: Matrix::zeros(n, n) },
            BlasRequest::Dtrsm { a: l.clone(), b: b.clone() },
            BlasRequest::Dasum { x: g.rng.normal_vec(n * 8) },
            BlasRequest::Drot { x: g.rng.normal_vec(n * 8),
                                y: g.rng.normal_vec(n * 8), c: 0.6, s: 0.8 },
            BlasRequest::Dger { alpha: 0.7, x: g.rng.normal_vec(n),
                                y: g.rng.normal_vec(n), a: a.clone() },
            BlasRequest::Dsymv { alpha: 1.0, a: a.clone(),
                                 x: g.rng.normal_vec(n), beta: 0.2,
                                 y: g.rng.normal_vec(n) },
            BlasRequest::Dtrmv { a: l.clone(), x: g.rng.normal_vec(n) },
            BlasRequest::Dsymm { alpha: 1.0, a: a.clone(), b: b.clone(),
                                 beta: 0.3, c: Matrix::random(n, n, &mut g.rng) },
            BlasRequest::Dtrmm { alpha: 0.9, a: l.clone(), b: b.clone() },
        ];
        let fault = Fault {
            step: g.rng.below(8),
            i: g.rng.below(n),
            j: g.rng.below(n),
            delta: g.rng.range(1.0, 1e8),
        };
        for req in reqs {
            let want = run_native(&req, Impl::Naive, &profile,
                                  FtPolicy::None, None);
            let got = run_native(&req, Impl::Tuned, &profile,
                                 FtPolicy::Hybrid, Some(fault));
            ensure(got.ft.errors_detected >= 1,
                   format!("{}: undetected fault {fault:?}", req.routine()))?;
            ensure(results_match(&got.result, &want.result, 1e-6),
                   format!("{}: wrong answer escaped under {fault:?}",
                           req.routine()))?;
        }
        Ok(())
    });
}

/// Clean protected runs must be bit-identical across repeated executions
/// (determinism of the FT machinery).
#[test]
fn protected_runs_are_deterministic() {
    let profile = Profile::default();
    let mut rng = Rng::new(0xD5);
    let n = 96;
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let req = BlasRequest::Dgemm {
        alpha: 1.0, a, b, beta: 0.0, c: Matrix::zeros(n, n),
    };
    let r1 = run_native(&req, Impl::Tuned, &profile, FtPolicy::Hybrid, None);
    let r2 = run_native(&req, Impl::Tuned, &profile, FtPolicy::Hybrid, None);
    assert_eq!(r1.result.as_matrix().unwrap().data,
               r2.result.as_matrix().unwrap().data);
}

/// Injector plans drive a full 20-error experiment (the paper's setup):
/// all 20 strikes across 20 runs are detected and corrected.
#[test]
fn twenty_errors_per_routine_all_corrected() {
    let profile = Profile::default();
    let mut rng = Rng::new(0x20);
    let n = 128;
    let l = Matrix::random_lower_triangular(n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let req = BlasRequest::Dtrsm { a: l, b };
    let want = run_native(&req, Impl::Naive, &profile, FtPolicy::None, None);

    let cfg = InjectorConfig { count: 20, ..Default::default() };
    let mut inj = Injector::plan(&cfg, 20, 16, n);
    let mut detected = 0;
    for step in 0..20 {
        let fault = inj.take(step);
        assert!(fault.is_some(), "plan must strike every run");
        let got = run_native(&req, Impl::Tuned, &profile,
                             FtPolicy::Hybrid, fault);
        detected += got.ft.errors_detected;
        assert!(results_match(&got.result, &want.result, 1e-6),
                "run {step}: wrong answer");
    }
    assert_eq!(detected, 20, "all 20 injected errors must be detected");
}

/// The three native variants agree on every routine (blocked and tuned
/// vs the naive oracle) at a non-trivial size.
#[test]
fn variant_agreement_matrix() {
    let profile = Profile::default();
    let mut rng = Rng::new(0xA9);
    let n = 160;
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let c = Matrix::random(n, n, &mut rng);
    let l = Matrix::random_lower_triangular(n, &mut rng);
    let reqs = vec![
        BlasRequest::Dscal { alpha: -2.5, x: rng.normal_vec(1000) },
        BlasRequest::Daxpy { alpha: 0.3, x: rng.normal_vec(1000),
                             y: rng.normal_vec(1000) },
        BlasRequest::Ddot { x: rng.normal_vec(1000), y: rng.normal_vec(1000) },
        BlasRequest::Dnrm2 { x: rng.normal_vec(1000) },
        BlasRequest::Dasum { x: rng.normal_vec(1000) },
        BlasRequest::Dgemv { alpha: 1.0, a: a.clone(), x: rng.normal_vec(n),
                             beta: 0.1, y: rng.normal_vec(n) },
        BlasRequest::Dtrsv { a: l.clone(), b: rng.normal_vec(n) },
        BlasRequest::Dgemm { alpha: 0.8, a: a.clone(), b: b.clone(),
                             beta: 0.2, c: c.clone() },
        BlasRequest::Dsymm { alpha: 1.0, a: a.clone(), b: b.clone(),
                             beta: 0.0, c: c.clone() },
        BlasRequest::Dtrmm { alpha: 1.0, a: l.clone(), b: b.clone() },
        BlasRequest::Dtrsm { a: l.clone(), b: b.clone() },
        BlasRequest::Dsyrk { alpha: 1.0, a: a.clone(), beta: 0.4,
                             c: c.clone() },
        BlasRequest::Drot { x: rng.normal_vec(1000), y: rng.normal_vec(1000),
                            c: 0.28, s: 0.96 },
        BlasRequest::Drotm { x: rng.normal_vec(1000), y: rng.normal_vec(1000),
                             param: [-1.0, 0.4, -0.3, 0.7, 1.1] },
        BlasRequest::Idamax { x: rng.normal_vec(1000) },
        BlasRequest::Dger { alpha: -0.6, x: rng.normal_vec(n),
                            y: rng.normal_vec(n), a: a.clone() },
        BlasRequest::Dsymv { alpha: 0.9, a: a.clone(), x: rng.normal_vec(n),
                             beta: -0.2, y: rng.normal_vec(n) },
        BlasRequest::Dtrmv { a: l.clone(), x: rng.normal_vec(n) },
    ];
    for req in reqs {
        let want = run_native(&req, Impl::Naive, &profile,
                              FtPolicy::None, None);
        for v in [Impl::Blocked, Impl::Tuned] {
            let got = run_native(&req, v, &profile, FtPolicy::None, None);
            assert!(results_match(&got.result, &want.result, 1e-7),
                    "{} differs under {:?}", req.routine(), v);
        }
    }
}

/// Downstream consumer: Cholesky built on the library solves correctly.
#[test]
fn cholesky_downstream() {
    let profile = Profile::default();
    let mut rng = Rng::new(0xC4);
    let n = 192;
    let a = Matrix::random_spd(n, &mut rng);
    let b = rng.normal_vec(n);
    let x = ftblas::apps::cholesky::solve_spd(&a, &b, 48, &profile.gemm)
        .expect("solvable");
    let mut r = vec![0.0; n];
    ftblas::blas::naive::dgemv(n, n, 1.0, &a.data, &x, 0.0, &mut r);
    let num: f64 = r.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum();
    let den: f64 = b.iter().map(|v| v * v).sum();
    assert!((num / den).sqrt() < 1e-8);
}

/// The unfused-ABFT policy also yields correct, protected results.
#[test]
fn unfused_policy_corrects() {
    let profile = Profile::default();
    let mut rng = Rng::new(0xAB);
    let n = 128;
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let req = BlasRequest::Dgemm {
        alpha: 1.0, a, b, beta: 0.0, c: Matrix::zeros(n, n),
    };
    let want = run_native(&req, Impl::Naive, &profile, FtPolicy::None, None);
    let fault = Fault { step: 0, i: 31, j: 77, delta: 4.2e5 };
    let got = run_native(&req, Impl::Tuned, &profile,
                         FtPolicy::AbftUnfused, Some(fault));
    assert!(got.ft.errors_detected >= 1);
    assert!(results_match(&got.result, &want.result, 1e-6));
}
