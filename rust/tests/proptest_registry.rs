//! Property tests on the kernel-registry/planner subsystem:
//!
//! - every in-process registry entry (routine × variant × policy ×
//!   threads ∈ {1,4}, GPU-sim tiers included; the PJRT peer's stub
//!   descriptors excluded) matches the naive oracle on random requests;
//! - the planner never selects a kernel whose capability list excludes
//!   the requested policy, and only grants threads to threaded kernels;
//! - the MT fused-ABFT DGEMM is reachable from the serving path when the
//!   profile grants threads, and merges band-local FtReports (one
//!   injected fault per thread band, all corrected).
//!
//! Uses the repo's seeded check harness (`util::check`) — proptest is
//! not vendored in this offline image; see DESIGN.md §9.

use ftblas::blas::Impl;
use ftblas::config::Profile;
use ftblas::coordinator::plan::{PlanCache, Planner, SelectionPolicy};
use ftblas::coordinator::registry::{ExecCtx, KernelRegistry};
use ftblas::coordinator::request::{Backend, BlasRequest, BlasResponse,
                                   BlasResult};
use ftblas::coordinator::router::execute_plan;
use ftblas::ft::injector::Fault;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::check::{check, ensure};
use ftblas::util::matrix::{allclose, Matrix};
use ftblas::util::rng::Rng;

/// Plan onto a pinned native variant and run the plan — the reference
/// executions these properties compare against.
fn run_native(req: &BlasRequest, variant: Impl, profile: &Profile,
              policy: FtPolicy, fault: Option<Fault>) -> BlasResponse {
    let plan = Planner::new(profile)
        .plan(req, &SelectionPolicy::for_variant(variant), policy)
        .expect("the native ladder serves every routine");
    execute_plan(req, &plan, profile, fault)
}

fn results_match(a: &BlasResult, b: &BlasResult, tol: f64) -> bool {
    match (a, b) {
        (BlasResult::Scalar(x), BlasResult::Scalar(y)) => {
            (x - y).abs() <= tol * (1.0 + y.abs())
        }
        (BlasResult::Vector(x), BlasResult::Vector(y)) => allclose(x, y, tol, tol),
        (BlasResult::Matrix(x), BlasResult::Matrix(y)) => {
            allclose(&x.data, &y.data, tol, tol)
        }
        _ => false,
    }
}

/// Build a random request for one routine at principal dimension n.
fn request_for(routine: &str, n: usize, rng: &mut Rng) -> BlasRequest {
    match routine {
        "dscal" => BlasRequest::Dscal { alpha: 1.3, x: rng.normal_vec(n * 8) },
        "daxpy" => BlasRequest::Daxpy {
            alpha: -0.7, x: rng.normal_vec(n * 8), y: rng.normal_vec(n * 8),
        },
        "ddot" => BlasRequest::Ddot {
            x: rng.normal_vec(n * 8), y: rng.normal_vec(n * 8),
        },
        "dnrm2" => BlasRequest::Dnrm2 { x: rng.normal_vec(n * 8) },
        "dasum" => BlasRequest::Dasum { x: rng.normal_vec(n * 8) },
        "drot" => BlasRequest::Drot {
            x: rng.normal_vec(n * 8), y: rng.normal_vec(n * 8),
            c: 0.6, s: 0.8,
        },
        "drotm" => BlasRequest::Drotm {
            x: rng.normal_vec(n * 8), y: rng.normal_vec(n * 8),
            param: [-1.0, 0.9, -0.2, 0.3, 1.1],
        },
        "idamax" => BlasRequest::Idamax { x: rng.normal_vec(n * 8) },
        "dgemv" => BlasRequest::Dgemv {
            alpha: 1.1, a: Matrix::random(n, n, rng), x: rng.normal_vec(n),
            beta: 0.4, y: rng.normal_vec(n),
        },
        "dtrsv" => BlasRequest::Dtrsv {
            a: Matrix::random_lower_triangular(n, rng), b: rng.normal_vec(n),
        },
        "dger" => BlasRequest::Dger {
            alpha: 0.9, x: rng.normal_vec(n), y: rng.normal_vec(n),
            a: Matrix::random(n, n, rng),
        },
        "dsymv" => BlasRequest::Dsymv {
            alpha: 1.0, a: Matrix::random_symmetric(n, rng),
            x: rng.normal_vec(n), beta: 0.2, y: rng.normal_vec(n),
        },
        "dtrmv" => BlasRequest::Dtrmv {
            a: Matrix::random_lower_triangular(n, rng), x: rng.normal_vec(n),
        },
        "dgemm" => BlasRequest::Dgemm {
            alpha: 0.9, a: Matrix::random(n, n, rng),
            b: Matrix::random(n, n, rng), beta: 0.5,
            c: Matrix::random(n, n, rng),
        },
        "dsymm" => BlasRequest::Dsymm {
            alpha: 1.2, a: Matrix::random(n, n, rng),
            b: Matrix::random(n, n, rng), beta: 0.4,
            c: Matrix::random(n, n, rng),
        },
        "dtrmm" => BlasRequest::Dtrmm {
            alpha: 0.7, a: Matrix::random_lower_triangular(n, rng),
            b: Matrix::random(n, n, rng),
        },
        "dtrsm" => BlasRequest::Dtrsm {
            a: Matrix::random_lower_triangular(n, rng),
            b: Matrix::random(n, n, rng),
        },
        "dsyrk" => BlasRequest::Dsyrk {
            alpha: 1.0, a: Matrix::random(n, n, rng), beta: 0.2,
            c: Matrix::random(n, n, rng),
        },
        other => panic!("no request builder for routine `{other}`"),
    }
}

/// Every registry entry, under every policy it claims and with thread
/// grants of 1 and 4, agrees with the naive oracle on clean runs.
#[test]
fn every_entry_matches_oracle_under_claimed_policies() {
    let reg = KernelRegistry::global();
    check("registry-oracle-matrix", 4, |g| {
        let n = 16 + 8 * g.rng.below(4);
        let profile = Profile::default();
        for entry in reg.entries() {
            if entry.backend == Backend::Pjrt {
                // peer-backend descriptors execute on the PJRT engine,
                // not in-process — their execute hooks are stubs
                continue;
            }
            let req = request_for(entry.routine, n, &mut g.rng);
            let want = run_native(&req, Impl::Naive, &profile,
                                  FtPolicy::None, None);
            for &policy in entry.policies {
                for threads in [1usize, 4] {
                    let ctx = ExecCtx {
                        req: &req,
                        profile: &profile,
                        policy,
                        faults: &[],
                        threads,
                    };
                    let (result, ft) = (entry.execute)(&ctx);
                    ensure(ft.errors_detected == 0,
                           format!("{}: clean run flagged under {}",
                                   entry.name, policy.name()))?;
                    ensure(results_match(&result, &want.result, 1e-7),
                           format!("{}: diverged from oracle under {} (t={})",
                                   entry.name, policy.name(), threads))?;
                }
            }
        }
        Ok(())
    });
}

/// The planner never selects a kernel whose capabilities exclude the
/// requested policy, always plans something, and only grants threads to
/// threaded kernels above their MR floor.
#[test]
fn planner_respects_capabilities() {
    let reg = KernelRegistry::global();
    check("planner-capabilities", 30, |g| {
        let routines = reg.routines();
        let routine = routines[g.rng.below(routines.len())];
        let n = 4 + g.rng.below(128);
        let threads = 1 + g.rng.below(8);
        let variant = Impl::ALL[g.rng.below(3)];
        let policy = FtPolicy::ALL[g.rng.below(4)];
        let profile = Profile::default().with_threads(threads);
        let planner = Planner::new(&profile);
        let sel = SelectionPolicy::for_variant(variant);
        let plan = planner.plan_dims(routine, n, &sel, policy);
        let plan = plan.ok_or_else(|| {
            format!("planner came up empty for {routine}/{} under {}",
                    variant.name(), policy.name())
        })?;
        ensure(plan.kernel.routine == routine, "planned foreign routine")?;
        ensure(plan.kernel.supports(policy),
               format!("{} does not serve {}", plan.kernel.name,
                       policy.name()))?;
        if plan.kernel.threaded {
            ensure(threads > 1, "threaded kernel on a serial profile")?;
            ensure(plan.threads == threads, "thread grant mismatch")?;
            ensure(plan.kernel.admits_dim(n, profile.gemm.mr),
                   "threaded kernel below its MR floor")?;
        } else {
            ensure(plan.threads == 1, "serial kernel granted threads")?;
        }
        Ok(())
    });
}

/// Admission-time memoization is transparent: for any random
/// `(routine, dim, policy, backend)` key, a plan-cache hit returns
/// exactly what a fresh planner resolution would — same kernel id,
/// same thread grant — and the hit/miss counters account for every
/// resolution.
#[test]
fn plan_cache_hits_equal_fresh_planner_resolutions() {
    let reg = KernelRegistry::global();
    check("plan-cache-transparent", 40, |g| {
        let threads = 1 + g.rng.below(8);
        let profile = Profile::default().with_threads(threads);
        let cache = PlanCache::new(profile.clone());
        let routines = reg.routines();
        let mut resolutions = 0u64;
        for round in 0..3 {
            for _ in 0..8 {
                let routine = routines[g.rng.below(routines.len())];
                // a handful of dims so later rounds re-hit cached keys
                let dim = 8 * (1 + g.rng.below(4));
                let policy = FtPolicy::ALL[g.rng.below(4)];
                let backend = [Backend::NativeNaive, Backend::NativeBlocked,
                               Backend::NativeTuned][g.rng.below(3)];
                let sel = SelectionPolicy::for_backend(backend);
                let cached = cache.resolve(routine, dim, policy, &sel);
                resolutions += 1;
                let fresh =
                    Planner::new(&profile).plan_dims(routine, dim, &sel,
                                                     policy);
                match (cached, fresh) {
                    (Some(c), Some(f)) => {
                        ensure(c.kernel_id == f.kernel_id,
                               format!("{routine}/{dim} round {round}: \
                                        cached {} != fresh {}",
                                       c.kernel.name, f.kernel.name))?;
                        ensure(c.threads == f.threads,
                               "thread grant drifted through the cache")?;
                        ensure(c.thread_cost() == f.thread_cost(),
                               "ledger cost drifted through the cache")?;
                    }
                    (None, None) => {}
                    _ => {
                        return Err(format!(
                            "{routine}/{dim}: cache and planner disagree \
                             on plannability"));
                    }
                }
            }
        }
        let (hits, misses) = cache.stats();
        ensure(hits + misses == resolutions,
               format!("counters leak: {hits}+{misses} != {resolutions}"))?;
        ensure(misses <= resolutions, "miss overcount")
    });
}

/// Serving-path acceptance: a DGEMM request on a profile with
/// `threads > 1` and a dimension above the MR-aligned floor executes
/// via `dgemm_abft_fused_mt` under the ABFT (hybrid) policy, and a
/// single injected fault is detected, corrected, and reported.
#[test]
fn mt_fused_gemm_serves_threaded_profiles() {
    let mut rng = Rng::new(0x4D54);
    let n = 96;
    let profile = Profile::default().with_threads(4);
    let req = BlasRequest::Dgemm {
        alpha: 1.0,
        a: Matrix::random(n, n, &mut rng),
        b: Matrix::random(n, n, &mut rng),
        beta: 0.0,
        c: Matrix::zeros(n, n),
    };
    let want = run_native(&req, Impl::Naive, &profile, FtPolicy::None, None);
    let fault = Fault { step: 0, i: n / 2, j: n / 3, delta: 6e4 };
    let resp = run_native(&req, Impl::Tuned, &profile, FtPolicy::Hybrid,
                          Some(fault));
    assert_eq!(resp.kernel, "dgemm/abft-fused-mt",
               "threaded profile must route to the MT fused kernel");
    assert!(resp.ft.errors_detected >= 1, "injected fault undetected");
    assert_eq!(resp.ft.errors_detected, resp.ft.errors_corrected);
    assert!(results_match(&resp.result, &want.result, 1e-7));
}

/// One fault per thread band through the registry entry: every band's
/// report is merged into the response (the band-local FT argument).
#[test]
fn mt_fused_gemm_merges_band_reports() {
    let mut rng = Rng::new(0xBA2D);
    let (n, threads) = (128usize, 4usize);
    let profile = Profile::default().with_threads(threads);
    let req = BlasRequest::Dgemm {
        alpha: 1.0,
        a: Matrix::random(n, n, &mut rng),
        b: Matrix::random(n, n, &mut rng),
        beta: 0.0,
        c: Matrix::zeros(n, n),
    };
    let want = run_native(&req, Impl::Naive, &profile, FtPolicy::None, None);
    // one strike in each thread band's row range (bands are contiguous
    // MR-aligned row slabs of ~n/threads rows)
    let band = n / threads;
    let faults: Vec<Fault> = (0..threads)
        .map(|t| Fault {
            step: 0,
            i: t * band + band / 2,
            j: (7 * t + 3) % n,
            delta: 5e4,
        })
        .collect();
    let entry = KernelRegistry::global()
        .find("dgemm/abft-fused-mt")
        .expect("MT fused kernel registered");
    let ctx = ExecCtx {
        req: &req,
        profile: &profile,
        policy: FtPolicy::Hybrid,
        faults: &faults,
        threads,
    };
    let (result, ft) = (entry.execute)(&ctx);
    assert_eq!(ft.errors_corrected, threads as u64,
               "merged report must count one correction per band: {ft:?}");
    assert_eq!(ft.errors_detected, ft.errors_corrected);
    assert!(results_match(&result, &want.result, 1e-7),
            "band corrections must restore the oracle result");
}
