//! Golden wire-conformance suite for the HTTP gateway
//! (`docs/PROTOCOL.md`): every status-code mapping the protocol
//! promises — 200 with a reproducible checksum, 400 for malformed /
//! unknown / plan-less envelopes (including unsatisfiable v2 `routing`
//! selections), 429 with `Retry-After` off a saturated cluster, 504
//! past the deadline — plus schema validation of the operational
//! routes (the `ftblas.backends.v1` capability inventory included),
//! the graceful-drain accounting, and a seeded injection campaign
//! driven entirely through the wire.

use std::time::Duration;

use ftblas::config::Profile;
use ftblas::coordinator::cluster::{Cluster, ClusterConfig, RetryPolicy};
use ftblas::coordinator::gateway::{self, Envelope, Gateway, GatewayConfig,
                                   result_checksum};
use ftblas::coordinator::http::fetch;
use ftblas::coordinator::request::{Backend, BlasRequest};
use ftblas::coordinator::router::Router;
use ftblas::ft::injector::{CampaignConfig, CampaignTarget};
use ftblas::ft::policy::FtPolicy;
use ftblas::util::json::Json;
use ftblas::util::matrix::Matrix;
use ftblas::util::rng::Rng;

/// A gateway on an ephemeral loopback port over a native cluster.
fn gateway_over(profile: Profile, policy: FtPolicy, cfg: GatewayConfig)
                -> (Gateway, Cluster, String) {
    let cluster_cfg = ClusterConfig::from_profile(&profile);
    let router = Router::native_only(profile.clone(), Backend::NativeTuned);
    let cluster = Cluster::start(router, policy, cluster_cfg);
    let gw = Gateway::bind("127.0.0.1:0", cluster.handle(), profile, policy,
                           cfg)
        .expect("gateway binds an ephemeral port");
    let addr = gw.local_addr().to_string();
    (gw, cluster, addr)
}

fn parse(body: &str) -> Json {
    Json::parse(body).unwrap_or_else(|e| panic!("body not JSON ({e}): {body}"))
}

fn str_of<'a>(doc: &'a Json, key: &str) -> Option<&'a str> {
    doc.get(key).and_then(Json::as_str)
}

/// End-to-end 200: the wire answer carries the response schema, echoes
/// the envelope, and its checksum is bit-identical to a direct
/// in-process call built from the same envelope — the reproducibility
/// contract of the seeded wire payload.
#[test]
fn wire_roundtrip_matches_the_direct_call() {
    let (gw, cluster, addr) = gateway_over(
        Profile::default().with_shards(2), FtPolicy::Hybrid,
        GatewayConfig::default());
    let mut env = Envelope::new("dgemm", 48);
    env.idempotency_key = Some("golden-1".into());
    let resp = fetch(&addr, "POST", "/v1/blas",
                     Some(&env.to_json().render())).unwrap();
    assert_eq!(resp.status, 200, "body: {}", resp.body);
    let doc = parse(&resp.body);
    assert_eq!(str_of(&doc, "schema"), Some(gateway::RESPONSE_SCHEMA));
    assert_eq!(str_of(&doc, "routine"), Some("dgemm"));
    assert_eq!(doc.get("dim").and_then(Json::as_f64), Some(48.0));
    assert_eq!(str_of(&doc, "policy"), Some("hybrid"));
    assert_eq!(str_of(&doc, "idempotency_key"), Some("golden-1"));
    assert!(str_of(&doc, "kernel").is_some(), "executed kernel named");
    let wire_sum = doc.get("checksum").and_then(Json::as_f64)
        .expect("200 body carries a checksum");
    let direct = cluster.handle()
        .call(env.build_request().expect("dgemm builds"))
        .expect("direct call succeeds");
    assert_eq!(wire_sum, result_checksum(&direct.result),
               "wire result must be bit-identical to the in-process call");
    let stats = gw.shutdown();
    assert_eq!((stats.accepted, stats.served, stats.s2xx), (1, 1, 1));
    cluster.shutdown();
}

/// The 400 family: malformed JSON, schema violations, unknown
/// routines (with the routine list as the diagnostic), FT-policy
/// mismatches, and a pinned variant no kernel serves — each named in
/// the error body.
#[test]
fn invalid_envelopes_map_to_400_with_diagnostics() {
    let (gw, cluster, addr) = gateway_over(
        Profile::default().with_shards(1), FtPolicy::Hybrid,
        GatewayConfig::default());
    let post = |body: &str| fetch(&addr, "POST", "/v1/blas", Some(body))
        .unwrap();

    let resp = post("{ this is not json");
    assert_eq!(resp.status, 400);
    assert!(parse(&resp.body).get("error").is_some());

    let resp = post(r#"{"schema":"ftblas.request.v1","routine":"ddot"}"#);
    assert_eq!(resp.status, 400);
    assert!(resp.body.contains("dim"), "names the missing field");

    let env = Envelope::new("zgemm", 32);
    let resp = post(&env.to_json().render());
    assert_eq!(resp.status, 400);
    let doc = parse(&resp.body);
    assert!(str_of(&doc, "error").unwrap().contains("zgemm"));
    let listed = doc.get("routines").and_then(Json::as_arr)
        .expect("diagnostic lists the served routines");
    assert_eq!(listed.len(), gateway::ROUTINES.len());

    // the cluster serves hybrid; asserting another policy is a 400
    let mut env = Envelope::new("ddot", 64);
    env.ft = Some(FtPolicy::None);
    let resp = post(&env.to_json().render());
    assert_eq!(resp.status, 400);
    assert!(resp.body.contains("mismatch"), "body: {}", resp.body);

    // serial `naive` kernels are unprotected, so pinning that variant
    // under a protecting policy has no candidate — the planner's
    // diagnostic comes back instead of a silent substitution
    let mut env = Envelope::new("dgemm", 32);
    env.variant = Some(ftblas::blas::Impl::Naive);
    let resp = post(&env.to_json().render());
    assert_eq!(resp.status, 400);
    assert!(resp.body.contains("no candidate kernel"),
            "body: {}", resp.body);
    assert!(resp.body.contains("naive"), "body: {}", resp.body);

    let stats = gw.shutdown();
    assert_eq!(stats.accepted, stats.served);
    assert_eq!(stats.s4xx, 5);
    cluster.shutdown();
}

/// An oversized `dim` is refused with 413 *before* operand generation:
/// building a dgemm at the asked dimension would allocate O(dim^2)
/// memory server-side, so the guard must fire on the envelope, not on
/// the allocation (a `{"dim": 200000}` POST is ~1 TB of operands).
#[test]
fn oversized_dim_is_refused_before_operand_generation() {
    let cfg = GatewayConfig { max_dim: 256, ..GatewayConfig::default() };
    let (gw, cluster, addr) = gateway_over(
        Profile::default().with_shards(1), FtPolicy::Hybrid, cfg);
    let post = |dim: u64| {
        let body = format!(
            r#"{{"schema":"ftblas.request.v1","routine":"dgemm","dim":{dim}}}"#);
        fetch(&addr, "POST", "/v1/blas", Some(&body)).unwrap()
    };
    // a would-be ~1 TB dgemm answers instantly instead of OOMing
    let resp = post(200_000);
    assert_eq!(resp.status, 413, "body: {}", resp.body);
    let doc = parse(&resp.body);
    assert!(str_of(&doc, "error").unwrap().contains("max-dim"));
    assert_eq!(doc.get("max_dim").and_then(Json::as_f64), Some(256.0));
    // a dim whose square overflows u64 arithmetic is equally refused
    let resp = post(u64::MAX);
    assert_ne!(resp.status, 200, "body: {}", resp.body);
    // at the cap itself the request is admitted and served
    let resp = post(256);
    assert_eq!(resp.status, 200, "body: {}", resp.body);
    let stats = gw.shutdown();
    assert_eq!(stats.accepted, stats.served);
    cluster.shutdown();
}

/// A saturated single-shard cluster sheds the wire submission: 429,
/// a whole-second `Retry-After` header, and the typed admission
/// diagnostic (shard, queue depth, watermark) in the body.
#[test]
fn saturated_cluster_answers_429_with_retry_after() {
    let mut profile =
        Profile::default().with_shards(1).with_admission_depth(1);
    profile.workers = 1;
    // no gateway-side retries: the test wants the shed surfaced, not
    // ridden out
    let cfg = GatewayConfig {
        retry: RetryPolicy { attempts: 0, ..RetryPolicy::default() },
        ..GatewayConfig::default()
    };
    let (gw, cluster, addr) = gateway_over(profile, FtPolicy::Hybrid, cfg);
    let handle = cluster.handle();
    // saturate: heavy DGEMMs through the same (only) shard until the
    // watermark sheds — the queue then holds hundreds of ms of work
    let mut rng = Rng::new(0x5A7);
    let mut rxs = Vec::new();
    let mut shed = false;
    for _ in 0..12 {
        let req = BlasRequest::Dgemm {
            alpha: 1.0,
            a: Matrix::random(512, 512, &mut rng),
            b: Matrix::random(512, 512, &mut rng),
            beta: 0.0,
            c: Matrix::zeros(512, 512),
        };
        match handle.submit(req) {
            Ok(rx) => rxs.push(rx),
            Err(_) => {
                shed = true;
                break;
            }
        }
    }
    assert!(shed, "direct submissions must reach the admission watermark");
    let resp = fetch(&addr, "POST", "/v1/blas",
                     Some(&Envelope::new("dgemm", 512).to_json().render()))
        .unwrap();
    assert_eq!(resp.status, 429, "body: {}", resp.body);
    let after: u64 = resp.header("retry-after")
        .expect("429 carries Retry-After")
        .parse()
        .expect("Retry-After is whole seconds");
    assert!(after >= 1);
    let doc = parse(&resp.body);
    assert_eq!(str_of(&doc, "kind"), Some("overloaded"));
    assert_eq!(doc.get("retries").and_then(Json::as_f64), Some(0.0));
    assert!(doc.get("queue_depth").is_some());
    assert!(doc.get("admission_limit").is_some());
    assert!(doc.get("retry_after_ms").and_then(Json::as_f64).unwrap()
            >= 1.0);
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    gw.shutdown();
    cluster.shutdown();
}

/// A deadline the execution cannot meet maps to 504 with the deadline
/// echoed, and the late completion still lands in the ledger (the
/// gateway abandons the wait, not the work).
#[test]
fn missed_deadline_maps_to_504() {
    let (gw, cluster, addr) = gateway_over(
        Profile::default().with_shards(1), FtPolicy::Hybrid,
        GatewayConfig::default());
    let mut env = Envelope::new("dgemm", 384);
    env.deadline_ms = Some(1);
    let resp = fetch(&addr, "POST", "/v1/blas",
                     Some(&env.to_json().render())).unwrap();
    assert_eq!(resp.status, 504, "body: {}", resp.body);
    let doc = parse(&resp.body);
    assert!(str_of(&doc, "error").unwrap().contains("deadline"));
    assert_eq!(doc.get("deadline_ms").and_then(Json::as_f64), Some(1.0));
    // the body states the kept-running semantics: retrying a 504
    // immediately compounds load, the work itself was not cancelled
    assert_eq!(doc.get("request_abandoned").and_then(|v| match v {
        Json::Bool(b) => Some(*b),
        _ => None,
    }), Some(false));
    assert!(str_of(&doc, "note").unwrap().contains("keeps executing"));
    gw.shutdown();
    let snap = cluster.shutdown();
    assert_eq!(snap.completed, 1,
               "the abandoned request still executes and is accounted");
}

/// The operational routes serve live state under their committed
/// `ftblas.*.v1` schemas, and unknown routes / wrong methods map to
/// 404 / 405.
#[test]
fn ops_routes_validate_against_their_schemas() {
    let (gw, cluster, addr) = gateway_over(
        Profile::default().with_shards(2), FtPolicy::Hybrid,
        GatewayConfig::default());
    // drive one request so the ledger has content
    let ok = fetch(&addr, "POST", "/v1/blas",
                   Some(&Envelope::new("ddot", 1024).to_json().render()))
        .unwrap();
    assert_eq!(ok.status, 200);

    let health = fetch(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(health.status, 200);
    let doc = parse(&health.body);
    assert_eq!(str_of(&doc, "schema"), Some(gateway::HEALTH_SCHEMA));
    assert_eq!(str_of(&doc, "status"), Some("ok"));
    assert_eq!(doc.get("shards").and_then(Json::as_f64), Some(2.0));
    assert_eq!(str_of(&doc, "campaign"), Some("none"));
    assert_eq!(str_of(&doc, "policy"), Some("hybrid"));
    let pool = doc.get("pool").expect("healthz reports the compute pool");
    assert!(pool.get("enabled").is_some());
    assert!(pool.get("live").is_some());

    let metrics = fetch(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(metrics.status, 200);
    let doc = parse(&metrics.body);
    assert_eq!(str_of(&doc, "schema"), Some("ftblas.ledger.v1"),
               "/metrics serves the ledger snapshot verbatim");
    assert_eq!(doc.get("completed").and_then(Json::as_f64), Some(1.0));
    assert!(doc.get("errors").and_then(|e| e.get("escaped")).is_some());
    assert!(doc.get("pool").is_some());
    assert!(doc.get("arena").is_some());

    let topo = fetch(&addr, "GET", "/topology", None).unwrap();
    assert_eq!(topo.status, 200);
    let doc = parse(&topo.body);
    assert_eq!(str_of(&doc, "schema"), Some(gateway::TOPOLOGY_SCHEMA));
    let shards = doc.get("shards").and_then(Json::as_arr).unwrap();
    assert_eq!(shards.len(), 2);
    for (i, s) in shards.iter().enumerate() {
        assert_eq!(s.get("slot").and_then(Json::as_f64), Some(i as f64));
        assert!(s.get("salt").is_some(), "slot {i} reports its salt");
        assert!(s.get("queue_depth").is_some());
    }
    assert!(doc.get("next_generation").and_then(Json::as_f64).unwrap()
            >= 1.0);
    assert_eq!(doc.get("scale_ups").and_then(Json::as_f64), Some(0.0));

    let campaign = fetch(&addr, "GET", "/campaign", None).unwrap();
    assert_eq!(campaign.status, 200);
    let doc = parse(&campaign.body);
    assert_eq!(str_of(&doc, "schema"), Some(gateway::CAMPAIGN_SCHEMA));
    assert_eq!(doc.get("active").and_then(|v| match v {
        Json::Bool(b) => Some(*b),
        _ => None,
    }), Some(false));

    let backends = fetch(&addr, "GET", "/backends", None).unwrap();
    assert_eq!(backends.status, 200);
    let doc = parse(&backends.body);
    assert_eq!(str_of(&doc, "schema"), Some(gateway::BACKENDS_SCHEMA));
    let list = doc.get("backends").and_then(Json::as_arr).unwrap();
    assert_eq!(list.len(), 6, "every backend is inventoried");
    let mut kernels = 0;
    let mut selected = 0.0;
    for b in list {
        assert!(str_of(b, "backend").is_some());
        assert!(str_of(b, "health").is_some());
        selected += b.get("selected").and_then(Json::as_f64).unwrap();
        let ks = b.get("kernels").and_then(Json::as_arr).unwrap();
        kernels += ks.len();
        for k in ks {
            for field in ["name", "routine", "scheme", "precision",
                          "threaded", "max_dim", "policies",
                          "cpu_features", "selected"] {
                assert!(k.get(field).is_some(),
                        "kernel record missing `{field}`");
            }
        }
    }
    assert!(kernels > 30, "the full registry is inventoried");
    assert!(selected >= 1.0,
            "the served ddot shows up in the selection counts");

    let missing = fetch(&addr, "GET", "/nope", None).unwrap();
    assert_eq!(missing.status, 404);
    assert!(parse(&missing.body).get("routes").is_some(),
            "404 lists the routes");
    let wrong = fetch(&addr, "GET", "/v1/blas", None).unwrap();
    assert_eq!(wrong.status, 405);
    assert_eq!(wrong.header("allow"), Some("POST"));
    let wrong = fetch(&addr, "POST", "/healthz", Some("{}")).unwrap();
    assert_eq!(wrong.status, 405);
    assert_eq!(wrong.header("allow"), Some("GET"));

    gw.shutdown();
    cluster.shutdown();
}

/// The v2 `routing` overlay steers execution through the wire: a
/// gpu-sim pin runs the simulated warp-tier executor (named in the
/// response), the same envelope without routing rides the native tier,
/// and an unsatisfiable selection maps to 400 carrying the planner's
/// exhaustive per-descriptor diagnostics.
#[test]
fn v2_routing_pins_backends_and_rejects_unsatisfiable() {
    let (gw, cluster, addr) = gateway_over(
        Profile::default().with_shards(1), FtPolicy::Hybrid,
        GatewayConfig::default());
    let body = r#"{"schema":"ftblas.request.v2","routine":"dgemm",
                   "dim":48,"routing":{"backend":"gpu-sim"}}"#;
    let resp = fetch(&addr, "POST", "/v1/blas", Some(body)).unwrap();
    assert_eq!(resp.status, 200, "body: {}", resp.body);
    let doc = parse(&resp.body);
    assert_eq!(str_of(&doc, "backend"), Some("gpu-sim"));
    assert_eq!(str_of(&doc, "kernel"), Some("dgemm/gpusim-wmma16"),
               "dim 48 under hybrid lands on the 16-wide warp tier");
    // the same envelope without routing rides the native tier
    let resp = fetch(&addr, "POST", "/v1/blas",
                     Some(&Envelope::new("dgemm", 48).to_json().render()))
        .unwrap();
    assert_eq!(resp.status, 200, "body: {}", resp.body);
    assert_eq!(str_of(&parse(&resp.body), "backend"), Some("tuned"));
    // unsatisfiable: nothing serves f32; the 400 names the missed
    // capability for every considered descriptor
    let body = r#"{"schema":"ftblas.request.v2","routine":"dgemm",
                   "dim":48,"routing":{"require":["precision=f32"]}}"#;
    let resp = fetch(&addr, "POST", "/v1/blas", Some(body)).unwrap();
    assert_eq!(resp.status, 400, "body: {}", resp.body);
    assert!(resp.body.contains("no candidate kernel"),
            "body: {}", resp.body);
    assert!(resp.body.contains("precision=f32"), "body: {}", resp.body);
    // a pjrt pin on a native-only cluster passes the gateway preflight
    // (the gateway's base selection does not know the router) but is
    // denied at cluster admission — the NoCandidate arm of the wire
    // mapping
    let body = r#"{"schema":"ftblas.request.v2","routine":"dgemm",
                   "dim":48,"routing":{"backend":"pjrt"}}"#;
    let resp = fetch(&addr, "POST", "/v1/blas", Some(body)).unwrap();
    assert_eq!(resp.status, 400, "body: {}", resp.body);
    assert!(resp.body.contains("no_candidate"), "body: {}", resp.body);
    gw.shutdown();
    cluster.shutdown();
}

/// Graceful shutdown drains in-flight wire requests: clients that were
/// already accepted get complete 200 responses, the gateway's
/// accounting closes at `accepted == served`, and the retired cluster
/// ledger holds exactly the drained completions.
#[test]
fn graceful_shutdown_drains_inflight_requests_exactly() {
    let (gw, cluster, addr) = gateway_over(
        Profile::default().with_shards(1), FtPolicy::Hybrid,
        GatewayConfig::default());
    // four slow requests in flight (~hundreds of ms each on one shard)
    let clients: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut env = Envelope::new("dgemm", 512);
                env.seed = 100 + i;
                fetch(&addr, "POST", "/v1/blas",
                      Some(&env.to_json().render()))
            })
        })
        .collect();
    // let every client connect and get accepted before draining
    std::thread::sleep(Duration::from_millis(150));
    let stats = gw.shutdown();
    let mut oks = 0;
    for c in clients {
        let resp = c.join().unwrap()
            .expect("accepted connections get full responses");
        assert_eq!(resp.status, 200, "body: {}", resp.body);
        oks += 1;
    }
    assert_eq!(oks, 4);
    assert_eq!(stats.accepted, stats.served,
               "drain invariant: every accepted connection was served");
    assert_eq!(stats.accepted, 4);
    assert_eq!(stats.s2xx, 4);
    let snap = cluster.shutdown();
    assert_eq!(snap.completed, 4, "ledger retires exactly");
    assert_eq!(snap.failed, 0);
}

/// The soak gate's invariant, proven through the wire: a seeded
/// campaign strikes protected kernels under wire load, and the
/// `/metrics` snapshot shows every injected error detected, corrected,
/// and none escaped.
#[test]
fn campaign_under_wire_load_escapes_nothing() {
    let profile = Profile::default().with_shards(1).with_campaign(
        CampaignConfig {
            seed: 0xC0DE,
            rate_per_min: 1.0e6, // rate gate effectively open
            stride: 1,
            target: CampaignTarget::AllProtected,
            ..Default::default()
        });
    let (gw, cluster, addr) = gateway_over(profile, FtPolicy::Hybrid,
                                           GatewayConfig::default());
    for i in 0..24 {
        let mut env = Envelope::new("dgemm", 64);
        env.seed = i;
        let resp = fetch(&addr, "POST", "/v1/blas",
                         Some(&env.to_json().render())).unwrap();
        assert_eq!(resp.status, 200, "body: {}", resp.body);
    }
    let resp = fetch(&addr, "GET", "/metrics", None).unwrap();
    let doc = parse(&resp.body);
    let errors = doc.get("errors").expect("ledger has error outcomes");
    let count = |key: &str| errors.get(key).and_then(Json::as_f64).unwrap();
    assert!(count("injected") > 0.0,
            "the campaign must actually strike under wire load");
    assert_eq!(count("escaped"), 0.0,
               "no injected error may escape detection");
    assert_eq!(count("detected"), count("injected"));
    assert_eq!(count("corrected"), count("detected"));

    let resp = fetch(&addr, "GET", "/campaign", None).unwrap();
    let doc = parse(&resp.body);
    assert_eq!(doc.get("active").and_then(|v| match v {
        Json::Bool(b) => Some(*b),
        _ => None,
    }), Some(true));
    assert!(doc.get("injected").and_then(Json::as_f64).unwrap() > 0.0);
    assert_eq!(doc.get("stride").and_then(Json::as_f64), Some(1.0));

    // /healthz reflects the armed campaign too
    let resp = fetch(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(str_of(&parse(&resp.body), "campaign"), Some("active"));

    gw.shutdown();
    cluster.shutdown();
}
