//! Property tests on the serving plane's wire layer: the
//! `ftblas.request.v1`/`v2` envelope codec round-trips every
//! representable request (including hostile idempotency keys that
//! stress the JSON string escaper and random `routing` selection
//! overlays), and the HTTP/1.1 head parser is *total* — it never
//! panics on arbitrary byte prefixes, truncations, or mutations, and
//! oversized input hits the size caps with the right status code
//! instead of buying unbounded buffering.
//!
//! Uses the repo's seeded check harness (`util::check`) — proptest is
//! not vendored in this offline image; see DESIGN.md §9.

use ftblas::blas::Impl;
use ftblas::coordinator::gateway::{Envelope, ROUTINES};
use ftblas::coordinator::http::{
    parse_head, ParseError, MAX_BODY_BYTES, MAX_HEADERS, MAX_LINE_BYTES,
};
use ftblas::coordinator::plan::{CapRequirement, SelectionPolicy};
use ftblas::coordinator::request::Backend;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::check::{check, ensure, Gen};
use ftblas::util::json::Json;
use ftblas::util::rng::Rng;

// ----------------------------------------------------- envelope codec

/// Code points the random idempotency keys draw from: plain ASCII,
/// JSON-syntax characters that must be escaped, every class of control
/// character, accented/BMP text, surrogate-range neighbours, and
/// astral-plane scalars. Values (not literals) so the source stays
/// ASCII-clean.
const KEY_ALPHABET: &[u32] = &[
    0x41,    // 'A'
    0x7A,    // 'z'
    0x20,    // space
    0x22,    // '"'   (must escape)
    0x5C,    // '\\'  (must escape)
    0x2F,    // '/'
    0x00,    // NUL        (control, \u-escaped on the wire)
    0x01,    // SOH        (control)
    0x08,    // backspace  (renders as \u0008)
    0x09,    // tab        (short escape \t)
    0x0A,    // newline    (short escape \n)
    0x0D,    // CR         (short escape \r)
    0x1F,    // unit sep   (last control)
    0x7F,    // DEL (not a JSON control — passes through raw)
    0xE9,    // e-acute (2-byte UTF-8)
    0x2603,  // snowman (3-byte UTF-8)
    0xD7FF,  // last scalar below the surrogate range
    0xE000,  // first scalar above the surrogate range
    0xFFFD,  // replacement character
    0x1D11E, // musical G clef (astral — surrogate pair in \u form)
    0x1F600, // emoji (astral)
];

/// A random key over [`KEY_ALPHABET`], length 0..=24.
fn random_key(rng: &mut Rng) -> String {
    let len = rng.below(25);
    (0..len)
        .map(|_| {
            let cp = KEY_ALPHABET[rng.below(KEY_ALPHABET.len())];
            char::from_u32(cp).expect("alphabet holds scalars only")
        })
        .collect()
}

/// A random v2 `routing` overlay: ordered, duplicate-free backend
/// subsets (the wire codec preserves order and the parser rejects
/// nothing valid, so round-tripping wants canonical lists) plus a
/// subset of a distinct requirement pool.
fn random_routing(rng: &mut Rng) -> SelectionPolicy {
    let mut sel = SelectionPolicy::default();
    for be in Backend::ALL {
        if rng.below(4) == 0 {
            sel.prefer.push(be);
        }
        if rng.below(5) == 0 {
            sel.allow.push(be);
        }
        if rng.below(5) == 0 {
            sel.deny.push(be);
        }
    }
    let pool = [
        CapRequirement::Precision("f64".into()),
        CapRequirement::Threaded(false),
        CapRequirement::Batched(true),
        CapRequirement::Feature("avx2".into()),
    ];
    for r in pool {
        if rng.below(4) == 0 {
            sel.require.push(r);
        }
    }
    sel
}

/// A random valid envelope spanning the full field space.
fn random_envelope(g: &mut Gen) -> Envelope {
    let routine = ROUTINES[g.rng.below(ROUTINES.len())];
    let mut env = Envelope::new(routine, g.dim(1, 4096));
    env.seed = g.rng.next_u64();
    if g.rng.below(2) == 1 {
        env.variant = Some(Impl::ALL[g.rng.below(Impl::ALL.len())]);
    }
    if g.rng.below(2) == 1 {
        const POLICIES: [FtPolicy; 4] = [
            FtPolicy::None,
            FtPolicy::Hybrid,
            FtPolicy::AbftUnfused,
            FtPolicy::AbftWeighted,
        ];
        env.ft = Some(POLICIES[g.rng.below(POLICIES.len())]);
    }
    if g.rng.below(2) == 1 {
        env.deadline_ms = Some(1 + g.rng.below(120_000) as u64);
    }
    if g.rng.below(2) == 1 {
        env.idempotency_key = Some(random_key(&mut g.rng));
    }
    if g.rng.below(3) == 0 {
        env.routing = Some(random_routing(&mut g.rng));
    }
    env
}

/// Encode → render → parse → decode is the identity on every valid
/// envelope, byte-hostile idempotency keys included. This is the wire
/// contract: what a client serializes is exactly what the gateway
/// submits.
#[test]
fn envelope_roundtrips_through_the_wire_encoding() {
    check("envelope_roundtrip", 400, |g| {
        let env = random_envelope(g);
        let text = env.to_json().render();
        let back = Envelope::parse(&text)
            .map_err(|e| format!("decode of {text:?} failed: {e}"))?;
        ensure(back == env,
               format!("round-trip mismatch: {env:?} -> {back:?}"))?;
        // the rendered envelope is also plain valid JSON for any
        // third-party consumer
        Json::parse(&text)
            .map_err(|e| format!("render emitted invalid JSON: {e}"))?;
        Ok(())
    });
}

/// Every routine the envelope accepts builds a typed request: the
/// `ROUTINES` table and `build_request` dispatch cannot drift apart.
#[test]
fn every_wire_routine_builds_a_request() {
    check("routines_build", 60, |g| {
        let routine = ROUTINES[g.rng.below(ROUTINES.len())];
        let env = Envelope::new(routine, g.dim(1, 64));
        ensure(env.build_request().is_some(),
               format!("routine `{routine}` is listed but unbuildable"))
    });
}

// -------------------------------------------------- HTTP head parser

/// Render a syntactically valid request head (terminated by the blank
/// line) with a random method/target/header set.
fn random_head(rng: &mut Rng) -> Vec<u8> {
    const METHODS: [&str; 4] = ["GET", "POST", "PUT", "DELETE"];
    const TARGETS: [&str; 4] =
        ["/", "/v1/blas", "/healthz", "/metrics?verbose=1"];
    let mut head = format!("{} {} HTTP/1.1\r\n",
                           METHODS[rng.below(METHODS.len())],
                           TARGETS[rng.below(TARGETS.len())]);
    for i in 0..rng.below(6) {
        head.push_str(&format!("x-key-{i}: value-{}\r\n", rng.below(100)));
    }
    if rng.below(2) == 1 {
        head.push_str(&format!("content-length: {}\r\n", rng.below(512)));
    }
    head.push_str("\r\n");
    head.into_bytes()
}

/// Incremental-parse coherence: on a valid head, every strict prefix
/// reports "incomplete, read more" and every extension past the blank
/// line parses to the same consumed offset — no prefix panics, errs,
/// or parses early. This is exactly the contract `read_request` leans
/// on while bytes trickle in.
#[test]
fn every_prefix_of_a_valid_head_parses_incrementally() {
    check("head_prefixes", 120, |g| {
        let head = random_head(&mut g.rng);
        let full = parse_head(&head)
            .map_err(|e| format!("valid head rejected: {e:?}"))?;
        let (_, consumed) =
            full.ok_or("valid head reported incomplete")?;
        ensure(consumed == head.len(),
               format!("consumed {consumed} of {}", head.len()))?;
        for cut in 0..head.len() {
            match parse_head(&head[..cut]) {
                Ok(None) => {}
                Ok(Some(_)) => {
                    return Err(format!(
                        "prefix of {cut} bytes parsed as complete"))
                }
                Err(e) => {
                    return Err(format!(
                        "prefix of {cut} bytes errored: {e:?}"))
                }
            }
        }
        Ok(())
    });
}

/// Totality under corruption: flip random bytes in a valid head (or
/// feed pure garbage) and the parser must return *some* `Result` — any
/// verdict is acceptable, a panic or hang is not.
#[test]
fn parser_never_panics_on_mutated_or_garbage_bytes() {
    check("head_mutations", 200, |g| {
        let mut buf = if g.rng.below(4) == 0 {
            // pure garbage
            (0..g.rng.below(256))
                .map(|_| g.rng.next_u64() as u8)
                .collect::<Vec<u8>>()
        } else {
            let mut head = random_head(&mut g.rng);
            for _ in 0..1 + g.rng.below(8) {
                let at = g.rng.below(head.len());
                head[at] = g.rng.next_u64() as u8;
            }
            head
        };
        let _ = parse_head(&buf);
        // and again on a random truncation of the same bytes
        buf.truncate(g.rng.below(buf.len() + 1));
        let _ = parse_head(&buf);
        Ok(())
    });
}

/// Size caps answer with the right status instead of buffering: a
/// header line past `MAX_LINE_BYTES` — terminated or still streaming —
/// is `431`, one header too many is `431`, and a declared body past
/// `MAX_BODY_BYTES` is `413`. The caps fire on the *unterminated* tail
/// too, so a peer that never sends LF cannot grow the buffer.
#[test]
fn oversized_input_hits_the_caps_with_431_and_413() {
    check("size_caps", 80, |g| {
        let overshoot = 1 + g.rng.below(512);

        // (a) one huge header line, LF-terminated
        let mut buf = b"GET / HTTP/1.1\r\nx-pad: ".to_vec();
        buf.resize(buf.len() + MAX_LINE_BYTES + overshoot, b'a');
        let mut terminated = buf.clone();
        terminated.extend_from_slice(b"\r\n\r\n");
        match parse_head(&terminated) {
            Err(e @ ParseError::TooLarge(_)) => {
                ensure(e.status() == 431,
                       format!("terminated long line -> {}", e.status()))?
            }
            other => {
                return Err(format!(
                    "terminated long line -> {other:?}, want TooLarge"))
            }
        }

        // (b) the same line still streaming (no LF yet): the cap must
        // fire against the unterminated tail as well
        match parse_head(&buf) {
            Err(e @ ParseError::TooLarge(_)) => {
                ensure(e.status() == 431,
                       format!("streaming long line -> {}", e.status()))?
            }
            other => {
                return Err(format!(
                    "streaming long line -> {other:?}, want TooLarge"))
            }
        }

        // (c) one header more than MAX_HEADERS
        let mut many = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..=MAX_HEADERS {
            many.extend_from_slice(format!("x-h{i}: v\r\n").as_bytes());
        }
        many.extend_from_slice(b"\r\n");
        match parse_head(&many) {
            Err(e @ ParseError::TooLarge(_)) => {
                ensure(e.status() == 431,
                       format!("header flood -> {}", e.status()))?
            }
            other => {
                return Err(format!(
                    "header flood -> {other:?}, want TooLarge"))
            }
        }

        // (d) a declared body past the cap is refused at the head, with
        // 413, before a single body byte is read
        let big = MAX_BODY_BYTES + overshoot;
        let huge = format!(
            "POST /v1/blas HTTP/1.1\r\ncontent-length: {big}\r\n\r\n");
        let (head, _) = parse_head(huge.as_bytes())
            .map_err(|e| format!("huge-body head rejected early: {e:?}"))?
            .ok_or("huge-body head reported incomplete")?;
        match head.content_length() {
            Err(e @ ParseError::BodyTooLarge(_)) => {
                ensure(e.status() == 413,
                       format!("oversized body -> {}", e.status()))
            }
            other => Err(format!(
                "oversized body -> {other:?}, want BodyTooLarge")),
        }
    });
}
