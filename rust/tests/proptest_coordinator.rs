//! Property tests on the coordinator's invariants: batching (grouping,
//! FIFO fairness, conservation), routing (fallback totality, policy
//! monotonicity), request metadata consistency, server state under
//! concurrent load, and injector plan accounting.
//!
//! Uses the repo's seeded check harness (`util::check`) — proptest is not
//! vendored in this offline image; see DESIGN.md §9.

use std::collections::{HashMap, HashSet};

use ftblas::blas::Impl;
use ftblas::config::Profile;
use ftblas::coordinator::batcher::Batcher;
use ftblas::coordinator::cluster::{route, route_key, route_salted, salt_for};
use ftblas::coordinator::plan::{PlanCache, Planner, SelectionPolicy};
use ftblas::coordinator::registry::KernelRegistry;
use ftblas::coordinator::request::{Backend, BlasRequest, BlasResponse,
                                   Level};
use ftblas::coordinator::router::{execute_plan, Router};
use ftblas::coordinator::server::Server;
use ftblas::ft::injector::{Injector, InjectorConfig};
use ftblas::ft::policy::FtPolicy;
use ftblas::util::check::{check, ensure};
use ftblas::util::matrix::Matrix;
use ftblas::util::rng::Rng;

const ROUTINES: [&str; 5] = ["dscal", "ddot", "dgemv", "dgemm", "dtrsm"];

/// Plan onto a pinned native variant and run the plan — the direct
/// (serverless) executions these properties drive.
fn run_native(req: &BlasRequest, variant: ftblas::blas::Impl,
              profile: &Profile, policy: FtPolicy) -> BlasResponse {
    let plan = Planner::new(profile)
        .plan(req, &SelectionPolicy::for_variant(variant), policy)
        .expect("the native ladder serves every routine");
    execute_plan(req, &plan, profile, None)
}

/// Random (routine, shape) key stream for the batcher.
fn rand_key(rng: &mut Rng) -> (&'static str, usize) {
    let r = ROUTINES[rng.below(ROUTINES.len())];
    let n = [64usize, 128, 256][rng.below(3)];
    (r, n)
}

// ------------------------------------------------------------- batcher

/// Conservation: every pushed item is drained exactly once, no dupes,
/// no losses, regardless of the push pattern and max_batch.
#[test]
fn batcher_conserves_items() {
    check("batcher-conservation", 50, |g| {
        let n = g.dim(0, 200);
        let max_batch = 1 + g.rng.below(16);
        let mut b: Batcher<(&'static str, usize), usize> = Batcher::new(max_batch);
        for i in 0..n {
            let key = rand_key(&mut g.rng);
            b.push(key, i);
        }
        let mut seen = vec![false; n];
        while !b.is_empty() {
            let batch = b.next_batch();
            ensure(!batch.is_empty(), "empty batch from non-empty queue")?;
            ensure(batch.len() <= max_batch, "batch exceeds max_batch")?;
            for p in &batch {
                ensure(!seen[p.item], format!("item {} drained twice", p.item))?;
                seen[p.item] = true;
            }
        }
        ensure(seen.iter().all(|&s| s), "some item was lost")
    });
}

/// Homogeneity: every batch holds exactly one (routine, shape) key.
#[test]
fn batcher_batches_are_homogeneous() {
    check("batcher-homogeneous", 40, |g| {
        let n = g.dim(1, 150);
        let mut b: Batcher<(&'static str, usize), usize> =
            Batcher::new(1 + g.rng.below(8));
        for i in 0..n {
            b.push(rand_key(&mut g.rng), i);
        }
        while !b.is_empty() {
            let batch = b.next_batch();
            let key = batch[0].key;
            ensure(batch.iter().all(|p| p.key == key),
                   "mixed keys in one batch")?;
        }
        Ok(())
    });
}

/// Order: within a batch, seq numbers are strictly increasing (arrival
/// order preserved), and the head of each successive batch is the oldest
/// remaining request (FIFO fairness across groups).
#[test]
fn batcher_preserves_order() {
    check("batcher-order", 40, |g| {
        let n = g.dim(1, 150);
        let mut b: Batcher<(&'static str, usize), usize> =
            Batcher::new(1 + g.rng.below(8));
        for i in 0..n {
            b.push(rand_key(&mut g.rng), i);
        }
        let mut min_head_seq = 0u64;
        while !b.is_empty() {
            let batch = b.next_batch();
            for w in batch.windows(2) {
                ensure(w[0].seq < w[1].seq, "within-batch order broken")?;
            }
            // the head must be the oldest remaining request overall
            ensure(batch[0].seq >= min_head_seq, "head went backwards")?;
            min_head_seq = batch[0].seq + 1;
            // every other remaining request with the same key and room in
            // the batch must have been included up to max_batch
            Ok::<(), String>(())?;
        }
        Ok(())
    });
}

/// Cost-aware drains conserve items too: under a random admission
/// predicate that flips each round, every item still drains exactly
/// once, deferred groups are never lost, and an all-pass predicate
/// matches plain `next_batch`.
#[test]
fn batcher_conserves_under_admission_filters() {
    check("batcher-admission", 40, |g| {
        let n = g.dim(0, 150);
        let max_batch = 1 + g.rng.below(8);
        let mut b: Batcher<(&'static str, usize), usize> =
            Batcher::new(max_batch);
        for i in 0..n {
            b.push(rand_key(&mut g.rng), i);
        }
        let mut seen = vec![false; n];
        let mut stuck = 0;
        while !b.is_empty() {
            // randomly reject one routine per round; always admit after
            // a fruitless round so the drain terminates
            let blocked = ROUTINES[g.rng.below(ROUTINES.len())];
            let admit_all = stuck > 0;
            let d = b.next_batch_where(|k| admit_all || k.0 != blocked);
            ensure(d.batch.len() <= max_batch, "batch exceeds max_batch")?;
            if d.batch.is_empty() {
                ensure(d.deferred > 0,
                       "empty drain from non-empty queue must defer")?;
                stuck += 1;
                continue;
            }
            stuck = 0;
            for p in &d.batch {
                ensure(!seen[p.item], format!("item {} drained twice", p.item))?;
                seen[p.item] = true;
            }
        }
        ensure(seen.iter().all(|&s| s), "some item was lost")
    });
}

// -------------------------------------------------------------- router

/// Fallback totality: a router preferring PJRT with no backend resolves
/// every request to the tuned native kernels — requests never fail for
/// shape reasons.
#[test]
fn router_fallback_is_total() {
    check("router-fallback", 20, |g| {
        let n = 8 + 8 * g.rng.below(8);
        let router = Router::native_only(Profile::default(), Backend::Pjrt);
        let a = Matrix::random(n, n, &mut g.rng);
        let reqs = [
            BlasRequest::Dscal { alpha: 1.1, x: g.rng.normal_vec(n) },
            BlasRequest::Idamax { x: g.rng.normal_vec(n) },
            BlasRequest::Dgemm { alpha: 1.0, a: a.clone(), b: a.clone(),
                                 beta: 0.0, c: Matrix::zeros(n, n) },
        ];
        for req in reqs {
            for policy in [FtPolicy::None, FtPolicy::Hybrid] {
                let plan = router.plan(&req, policy).ok_or_else(|| {
                    "pjrt-less router must still plan".to_string()
                })?;
                ensure(plan.kernel.backend == Backend::NativeTuned,
                       "pjrt-less router must fall back to tuned")?;
                let resp = router.execute_planned(&plan, &req, None)
                    .map_err(|e| e.to_string())?;
                ensure(resp.backend == Backend::NativeTuned,
                       "executed on unexpected backend")?;
            }
        }
        Ok(())
    });
}

/// Policy monotonicity: protection never changes the mathematical result
/// beyond round-off — for any request and any variant, protected ==
/// unprotected within tolerance, and clean runs never report errors.
#[test]
fn protection_is_transparent_when_clean() {
    let profile = Profile::default();
    check("policy-transparent", 15, |g| {
        let n = 32 + 16 * g.rng.below(6);
        let a = Matrix::random(n, n, &mut g.rng);
        let l = Matrix::random_lower_triangular(n, &mut g.rng);
        let reqs = [
            BlasRequest::Daxpy { alpha: -0.7, x: g.rng.normal_vec(n * 4),
                                 y: g.rng.normal_vec(n * 4) },
            BlasRequest::Dsymv { alpha: 1.0, a: a.clone(),
                                 x: g.rng.normal_vec(n), beta: 0.0,
                                 y: vec![0.0; n] },
            BlasRequest::Dtrmm { alpha: 1.0, a: l.clone(),
                                 b: Matrix::random(n, n, &mut g.rng) },
        ];
        for req in reqs {
            let plain = run_native(&req, Impl::Tuned, &profile,
                                   FtPolicy::None);
            let prot = run_native(&req, Impl::Tuned, &profile,
                                  FtPolicy::Hybrid);
            ensure(prot.ft.errors_detected == 0,
                   format!("{}: false positive", req.routine()))?;
            let close = match (&plain.result, &prot.result) {
                (ftblas::coordinator::request::BlasResult::Vector(x),
                 ftblas::coordinator::request::BlasResult::Vector(y)) => {
                    ftblas::util::matrix::allclose(x, y, 1e-9, 1e-9)
                }
                (ftblas::coordinator::request::BlasResult::Matrix(x),
                 ftblas::coordinator::request::BlasResult::Matrix(y)) => {
                    ftblas::util::matrix::allclose(&x.data, &y.data, 1e-9, 1e-9)
                }
                _ => false,
            };
            ensure(close, format!("{}: protected diverged", req.routine()))?;
        }
        Ok(())
    });
}

// ------------------------------------------------------ request metadata

/// flops() and dim() are consistent: positive for non-empty inputs,
/// batch_key round-trips the routine name, level matches the routine
/// family.
#[test]
fn request_metadata_consistent() {
    check("request-metadata", 25, |g| {
        let n = 4 + g.rng.below(60);
        let a = Matrix::random(n, n, &mut g.rng);
        let reqs = [
            (BlasRequest::Dscal { alpha: 2.0, x: g.rng.normal_vec(n) },
             Level::L1),
            (BlasRequest::Drotm { x: g.rng.normal_vec(n),
                                  y: g.rng.normal_vec(n),
                                  param: [-1.0, 1.0, 0.0, 0.0, 1.0] },
             Level::L1),
            (BlasRequest::Dger { alpha: 1.0, x: g.rng.normal_vec(n),
                                 y: g.rng.normal_vec(n), a: a.clone() },
             Level::L2),
            (BlasRequest::Dtrmv { a: a.clone(), x: g.rng.normal_vec(n) },
             Level::L2),
            (BlasRequest::Dsyrk { alpha: 1.0, a: a.clone(), beta: 0.0,
                                  c: Matrix::zeros(n, n) },
             Level::L3),
        ];
        for (req, lvl) in reqs {
            ensure(req.level() == lvl,
                   format!("{}: wrong level", req.routine()))?;
            ensure(req.flops() > 0.0, "flops must be positive")?;
            ensure(req.dim() == n, "dim mismatch")?;
            ensure(req.batch_key() == (req.routine(), n), "batch key")?;
        }
        Ok(())
    });
}

// -------------------------------------------------------------- server

/// Server state invariant: across a random concurrent workload, the
/// metrics ledger balances — completed + failed == submitted, and with a
/// clean (no-injection) run no errors are ever reported.
#[test]
fn server_ledger_balances() {
    check("server-ledger", 5, |g| {
        let n = 48;
        let router = Router::native_only(Profile::default(),
                                         Backend::NativeTuned);
        let server = Server::start(router, FtPolicy::Hybrid,
                                   2 + g.rng.below(3), None, 0);
        let handle = server.handle();
        let total = 20 + g.rng.below(30);
        let mut rxs = Vec::new();
        for _ in 0..total {
            let req = match g.rng.below(3) {
                0 => BlasRequest::Dscal { alpha: 1.5,
                                          x: g.rng.normal_vec(256) },
                1 => BlasRequest::Ddot { x: g.rng.normal_vec(256),
                                         y: g.rng.normal_vec(256) },
                _ => BlasRequest::Dgemv {
                    alpha: 1.0,
                    a: Matrix::random(n, n, &mut g.rng),
                    x: g.rng.normal_vec(n),
                    beta: 0.0,
                    y: vec![0.0; n],
                },
            };
            rxs.push(handle.submit(req));
        }
        for rx in rxs {
            let resp = rx.recv().map_err(|e| e.to_string())?
                .map_err(|e| e.to_string())?;
            ensure(resp.ft.errors_detected == 0, "clean run flagged")?;
        }
        let snap = server.shutdown();
        ensure(snap.completed + snap.failed == total as u64,
               format!("ledger off: {} + {} != {}", snap.completed,
                       snap.failed, total))?;
        ensure(snap.errors_detected == 0 && snap.errors_corrected == 0,
               "phantom errors in ledger")
    });
}

// ------------------------------------------------------- shard routing

/// Determinism: the same `(routine, dim, policy)` resolves — through a
/// fresh plan cache each time — to the same routing key and the same
/// shard at any fixed shard count, for both serving profiles. This is
/// the property that keeps a kernel's traffic pinned to one shard, so
/// shard-local kernel-keyed batching stays effective.
#[test]
fn shard_routing_is_deterministic() {
    check("cluster-routing-deterministic", 40, |g| {
        let profile = if g.rng.below(2) == 0 {
            Profile::skylake_sim()
        } else {
            Profile::cascade_sim()
        };
        let routines = ["dscal", "ddot", "dnrm2", "dgemv", "dtrsv", "dgemm",
                        "dsymm", "dtrmm", "dtrsm"];
        let routine = routines[g.rng.below(routines.len())];
        let dim = [32usize, 48, 64, 96, 128][g.rng.below(5)];
        let policy = FtPolicy::ALL[g.rng.below(4)];
        let key = |_: usize| -> Result<u64, String> {
            // a fresh cache per resolution: memoization cannot be what
            // makes routing stable
            let cache = PlanCache::new(profile.clone());
            let sel = SelectionPolicy::for_backend(Backend::NativeTuned);
            let plan = cache.resolve(routine, dim, policy, &sel)
                .ok_or_else(|| "native requests always plan".to_string())?;
            Ok(route_key(&plan))
        };
        let (k1, k2) = (key(0)?, key(1)?);
        ensure(k1 == k2, format!("{routine}/{dim}: routing key unstable"))?;
        for shards in 1..=6 {
            let depths = vec![0usize; shards];
            ensure(route(k1, &depths) == route(k2, &depths),
                   format!("{routine}/{dim}: shard flapped at {shards}"))?;
        }
        Ok(())
    });
}

/// Coverage: the registry's kernel-id key space spreads over every
/// shard for the cluster sizes the profiles ship (no shard is
/// unreachable, so a mixed workload uses the whole tier).
#[test]
fn shard_routing_covers_all_shards() {
    let ids = KernelRegistry::global().entries().len() as u64;
    for shards in [2usize, 3, 4, 8] {
        let depths = vec![0usize; shards];
        let used: HashSet<usize> =
            (0..ids).map(|k| route(k, &depths)).collect();
        assert_eq!(used.len(), shards,
                   "{shards} shards: kernel ids only reach {:?}", used);
    }
}

/// The elastic-migration invariant, grow side: appending a shard with
/// any fresh-generation salt moves **only the intended slice** of the
/// kernel-id key space — a key changes owner iff its new owner is the
/// new shard (survivors' scores are untouched by construction, so
/// nothing can reshuffle between them).
#[test]
fn regrown_shard_migrates_only_its_own_slice() {
    check("cluster-resalt-grow", 40, |g| {
        let ids = KernelRegistry::global().entries().len() as u64;
        let shards = 1 + g.rng.below(5);
        // a topology with arbitrary spawn generations per slot — the
        // state an elastic cluster reaches after any grow/shrink history
        let salts: Vec<u64> = (0..shards)
            .map(|s| salt_for(s, g.rng.below(6) as u64))
            .collect();
        let grown = {
            let mut v = salts.clone();
            v.push(salt_for(shards, 1 + g.rng.below(8) as u64));
            v
        };
        let depths_old = vec![0usize; shards];
        let depths_new = vec![0usize; shards + 1];
        let mut migrated = 0u64;
        for key in 0..ids {
            let before = route_salted(key, &salts, &depths_old);
            let after = route_salted(key, &grown, &depths_new);
            if before != after {
                ensure(after == shards,
                       format!("key {key} moved {before}→{after}, not to \
                                the new shard"))?;
                migrated += 1;
            }
        }
        ensure(migrated < ids,
               "growth must never migrate the whole key space")
    });
}

/// The elastic-migration invariant, shrink side: removing the top
/// shard re-homes exactly the keys it owned; every other key keeps its
/// shard (this is why the scale-down victim is always the newest slot).
#[test]
fn draining_the_top_shard_moves_only_its_keys() {
    check("cluster-resalt-shrink", 40, |g| {
        let ids = KernelRegistry::global().entries().len() as u64;
        let shards = 2 + g.rng.below(5);
        let salts: Vec<u64> = (0..shards)
            .map(|s| salt_for(s, g.rng.below(6) as u64))
            .collect();
        let shrunk = salts[..shards - 1].to_vec();
        let depths_old = vec![0usize; shards];
        let depths_new = vec![0usize; shards - 1];
        for key in 0..ids {
            let before = route_salted(key, &salts, &depths_old);
            let after = route_salted(key, &shrunk, &depths_new);
            if before == shards - 1 {
                ensure(after < shards - 1, "victim keys must re-home")?;
            } else {
                ensure(after == before,
                       format!("key {key} flapped {before}→{after} though \
                                its shard survived"))?;
            }
        }
        Ok(())
    });
}

/// Re-salting is what makes a *regrown* slot claim a fresh slice: the
/// same slot at different generations owns visibly different key sets
/// (checked over the kernel-id space the cluster actually routes).
#[test]
fn fresh_generation_salts_change_the_slice() {
    let ids = KernelRegistry::global().entries().len() as u64;
    let base = salt_for(0, 0);
    let slice = |gen: u64| -> Vec<u64> {
        (0..ids)
            .filter(|&k| route_salted(k, &[base, salt_for(1, gen)], &[0, 0])
                         == 1)
            .collect()
    };
    let gen0 = slice(0);
    assert!(!gen0.is_empty(), "slot 1 must own some kernel ids");
    assert!((1..4).any(|g| slice(g) != gen0),
            "regrowing slot 1 must eventually claim a different slice");
}

/// Route keys follow the *plan*, not the request shape: the same
/// `(routine, dim, policy)` under two selection policies that resolve
/// to different kernels routes under different keys, and each key is
/// exactly the planned kernel's id (there is no unplanned key space).
#[test]
fn route_keys_are_selection_sensitive_and_id_valued() {
    check("cluster-routing-selection", 20, |g| {
        let profile = Profile::default();
        let dim = [32usize, 48, 64][g.rng.below(3)];
        let planner = Planner::new(&profile);
        let mut keys = Vec::new();
        for be in [Backend::NativeNaive, Backend::NativeTuned] {
            let sel = SelectionPolicy::for_backend(be);
            let plan = planner
                .plan_dims("dgemm", dim, &sel, FtPolicy::None)
                .ok_or_else(|| "native dgemm always plans".to_string())?;
            ensure(route_key(&plan) == plan.kernel_id.0 as u64,
                   "route key must be the planned kernel id")?;
            keys.push(route_key(&plan));
        }
        ensure(keys[0] != keys[1],
               "distinct planned kernels must route under distinct keys")
    });
}

// ------------------------------------------------------------ injector

/// Plan accounting: an injector plan holds min(count, steps) strikes,
/// each within its configured bounds, and `take` consumes each strike
/// exactly once when the step stream is walked in order.
#[test]
fn injector_plan_accounting() {
    check("injector-plan", 40, |g| {
        let steps = 1 + g.rng.below(60);
        let count = g.rng.below(40);
        let m = 4 + g.rng.below(200);
        let n = 4 + g.rng.below(200);
        let cfg = InjectorConfig { count, seed: 7 + g.case as u64,
                                   ..Default::default() };
        let mut inj = Injector::plan(&cfg, steps, m, n);
        ensure(inj.planned() == count.min(steps),
               "plan must hold min(count, steps) strikes")?;
        let mut taken = 0;
        for step in 0..steps {
            if let Some(f) = inj.take(step) {
                ensure(f.step == step, "strike served at wrong step")?;
                ensure(f.i < m && f.j < n, "position out of bounds")?;
                let mag = f.delta.abs();
                ensure((cfg.min_magnitude..=cfg.max_magnitude).contains(&mag),
                       format!("delta {} out of range", f.delta))?;
                taken += 1;
            }
        }
        ensure(taken == inj.planned(),
               format!("took {taken}, planned {}", inj.planned()))?;
        ensure(inj.remaining() == 0, "strikes left after drain")
    });
}

// ------------------------------------------------- per-key batch stats

/// Driving the batcher with a realistic mixed workload: the number of
/// batches per key is ceil(count_key / max_batch) when the key's requests
/// arrive contiguously.
#[test]
fn batcher_contiguous_batch_count() {
    check("batcher-count", 30, |g| {
        let max_batch = 1 + g.rng.below(8);
        let mut b: Batcher<(&'static str, usize), u32> = Batcher::new(max_batch);
        let mut counts: HashMap<(&'static str, usize), usize> = HashMap::new();
        // contiguous runs per key
        for _ in 0..g.dim(1, 6) {
            let key = rand_key(&mut g.rng);
            let k = 1 + g.rng.below(20);
            for _ in 0..k {
                b.push(key, 0);
            }
            *counts.entry(key).or_default() += k;
        }
        let mut batches: HashMap<(&'static str, usize), usize> = HashMap::new();
        while !b.is_empty() {
            let batch = b.next_batch();
            *batches.entry(batch[0].key).or_default() += 1;
        }
        for (key, cnt) in counts {
            let got = batches.get(&key).copied().unwrap_or(0);
            ensure(got == cnt.div_ceil(max_batch),
                   format!("{key:?}: {got} batches for {cnt} items"))?;
        }
        Ok(())
    });
}
