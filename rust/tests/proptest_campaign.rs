//! Property tests for the injection-campaign schedule: the per-kernel
//! strike schedule must be **deterministic** (a pure function of the
//! campaign seed + `KernelId` + occurrence index) and **partition
//! exact** under elastic grow/shrink — however the rendezvous topology
//! slices the kernel space across shards, the union of the per-shard
//! strike sets equals the fixed-topology schedule, every strike fires
//! on exactly one shard, and a kernel migrated by a re-salt continues
//! its occurrence sequence instead of replaying it (no double
//! injection).

use std::collections::{HashMap, HashSet};

use ftblas::coordinator::cluster::{route_salted_with, salt_for};
use ftblas::coordinator::registry::{KernelId, Scheme};
use ftblas::ft::injector::{CampaignConfig, CampaignTarget, InjectionCampaign};
use ftblas::util::check::{check, ensure};

fn unbounded(seed: u64, stride: u64) -> CampaignConfig {
    CampaignConfig {
        seed,
        stride,
        rate_per_min: f64::INFINITY,
        target: CampaignTarget::AllProtected,
        ..Default::default()
    }
}

/// Schedule determinism: two campaigns from equal configs agree on
/// every (kernel, occurrence) decision and on the planted fault, and
/// candidates are exactly stride-spaced per kernel.
#[test]
fn campaign_schedule_is_pure() {
    check("campaign-schedule-pure", 40, |g| {
        let stride = 1 + g.rng.below(6) as u64;
        let seed = g.rng.next_u64();
        let a = unbounded(seed, stride);
        let b = unbounded(seed, stride);
        for _ in 0..8 {
            let k = KernelId(g.rng.below(96) as u16);
            let mut hits = Vec::new();
            for occ in 0..64u64 {
                ensure(a.is_strike(k, occ) == b.is_strike(k, occ),
                       "schedules from equal configs must agree")?;
                if a.is_strike(k, occ) {
                    ensure(a.fault_at(k, occ, 32, 32)
                           == b.fault_at(k, occ, 32, 32),
                           "planted faults must agree")?;
                    let f = a.fault_at(k, occ, 32, 32);
                    ensure(f.i < 32 && f.j < 32, "fault outside the output")?;
                    hits.push(occ);
                }
            }
            ensure(!hits.is_empty(), "64 occurrences cover any stride <= 6")?;
            ensure(hits[0] < stride, "phase lands in the first stride")?;
            ensure(hits.windows(2).all(|w| w[1] - w[0] == stride),
                   format!("stride {stride} spacing violated: {hits:?}"))?;
        }
        Ok(())
    });
}

/// Partition exactness under grow/shrink: replay a random elastic walk
/// (grow with fresh-generation salts, shrink the newest slot) while
/// kernels execute through ONE shared campaign — the shape the cluster
/// threads through its `Arc<Router>`. At every step each kernel is
/// routed to exactly one live shard, so attributing each armed strike
/// to the owner shard partitions the strike set. The union over shards
/// must equal the fixed-topology schedule over the claimed occurrence
/// ranges, with every strike attributed exactly once and occurrence
/// sequences continuing across migrations.
#[test]
fn campaign_partitions_exactly_under_grow_shrink() {
    check("campaign-partition-exact", 25, |g| {
        let stride = 1 + g.rng.below(5) as u64;
        let cfg = unbounded(g.rng.next_u64(), stride);
        let campaign = InjectionCampaign::new(cfg.clone());
        // a handful of kernels; ids from the registry's id range
        let kernels: Vec<KernelId> =
            (0..6).map(|_| KernelId(g.rng.below(96) as u16)).collect();
        let mut salts = vec![salt_for(0, 0)];
        let mut next_generation = 1u64;
        let mut claimed: HashMap<u16, u64> = HashMap::new();
        // strikes attributed to the shard that executed them, keyed by
        // the slot's salt (slots are reused across generations; salts
        // are unique per spawn)
        let mut by_shard: HashMap<u64, HashSet<(u16, u64)>> = HashMap::new();
        for _epoch in 0..12 {
            // random scale event between epochs: grow (fresh salt) or
            // shrink (drop the newest slot), inside [1, 4] shards
            match g.rng.below(3) {
                0 if salts.len() < 4 => {
                    salts.push(salt_for(salts.len(), next_generation));
                    next_generation += 1;
                }
                1 if salts.len() > 1 => {
                    salts.pop();
                }
                _ => {}
            }
            // each kernel executes a few times; routing owns WHERE,
            // the campaign owns WHETHER
            for &k in &kernels {
                let shard =
                    route_salted_with(k.0 as u64, &salts, |_| 0);
                for _ in 0..(1 + g.rng.below(4)) {
                    let occurrence = *claimed.get(&k.0).unwrap_or(&0);
                    let fault = campaign.arm(k, Scheme::Dmr, 64);
                    claimed.insert(k.0, occurrence + 1);
                    ensure(campaign.occurrences_of(k) == occurrence + 1,
                           "occurrence counters must be cluster-wide and \
                            monotone across migrations")?;
                    ensure(fault.is_some() == cfg.is_strike(k, occurrence),
                           "an unbounded campaign must realize exactly \
                            the pure schedule")?;
                    if fault.is_some() {
                        let fresh = by_shard
                            .entry(salts[shard])
                            .or_default()
                            .insert((k.0, occurrence));
                        ensure(fresh, "a strike fired twice")?;
                    }
                }
            }
        }
        // union over shard slices == the fixed-topology schedule over
        // the claimed ranges, and the slices are pairwise disjoint
        let mut union: HashSet<(u16, u64)> = HashSet::new();
        let mut total = 0usize;
        for slice in by_shard.values() {
            total += slice.len();
            union.extend(slice.iter().copied());
        }
        ensure(union.len() == total,
               "shard slices overlap: double injection")?;
        let reference: HashSet<(u16, u64)> = claimed
            .iter()
            .flat_map(|(&k, &n)| {
                let cfg = &cfg;
                (0..n).filter(move |&o| cfg.is_strike(KernelId(k), o))
                      .map(move |o| (k, o))
            })
            .collect();
        ensure(union == reference,
               format!("union of shard slices ({}) != fixed-topology \
                        schedule ({})", union.len(), reference.len()))?;
        Ok(())
    });
}

/// Re-salting a slot moves kernels between shards but never re-arms a
/// consumed schedule entry: a kernel executed before and after a
/// migration sees strictly increasing occurrences, so the strike count
/// equals the pure schedule's count over the whole range.
#[test]
fn migration_never_replays_consumed_strikes() {
    check("campaign-no-replay", 25, |g| {
        let stride = 1 + g.rng.below(4) as u64;
        let cfg = unbounded(g.rng.next_u64(), stride);
        let campaign = InjectionCampaign::new(cfg.clone());
        let k = KernelId(g.rng.below(96) as u16);
        let total = 40 + g.rng.below(40) as u64;
        let mut armed = 0u64;
        // "migrate" the kernel between phases by changing which shard
        // executes it — invisible to the campaign, as it must be
        for _ in 0..total {
            if campaign.arm(k, Scheme::AbftFused, 48).is_some() {
                armed += 1;
            }
        }
        let expected =
            (0..total).filter(|&o| cfg.is_strike(k, o)).count() as u64;
        ensure(armed == expected,
               format!("armed {armed} != scheduled {expected}"))?;
        ensure(campaign.injected() == armed, "injected counter drifted")?;
        Ok(())
    });
}
