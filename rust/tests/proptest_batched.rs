//! Property and end-to-end tests on the batch-fused small-GEMM path:
//! batched drivers must be indistinguishable from per-item kernel calls
//! (bitwise for the unprotected frames, per-item FT accounting for the
//! fused-ABFT frame), and the server's fusion fast path must keep the
//! campaign ledger exactly balanced.
//!
//! Uses the repo's seeded check harness (`util::check`) — proptest is not
//! vendored in this offline image; see DESIGN.md §9.

use ftblas::blas::batched::{self, GemmItem};
use ftblas::blas::level3::{self, GemmParams};
use ftblas::blas::{naive, simd};
use ftblas::config::Profile;
use ftblas::coordinator::request::{Backend, BlasRequest};
use ftblas::coordinator::router::Router;
use ftblas::coordinator::server::Server;
use ftblas::ft::injector::CampaignConfig;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::check::{check, ensure};
use ftblas::util::matrix::{allclose, Matrix};
use ftblas::util::rng::Rng;

/// One random batch item spec: (m, n, k, alpha, beta, a, b, c0).
type Spec = (usize, usize, usize, f64, f64, Vec<f64>, Vec<f64>, Vec<f64>);

fn random_specs(rng: &mut Rng, count: usize) -> Vec<Spec> {
    (0..count)
        .map(|i| {
            let m = 1 + rng.below(48);
            let n = 1 + rng.below(32);
            let k = 1 + rng.below(32);
            let alpha = [1.0, 0.6, -1.5][i % 3];
            let beta = [0.0, 1.0, -0.3][(i + 1) % 3];
            let a = Matrix::random(m, k, rng).data;
            let b = Matrix::random(k, n, rng).data;
            let c = Matrix::random(m, n, rng).data;
            (m, n, k, alpha, beta, a, b, c)
        })
        .collect()
}

/// Batched execution is unobservable from outside: for any batch shape
/// mix and any thread grant, both unprotected batched drivers reproduce
/// the per-item serial kernel results bitwise.
#[test]
fn batched_drivers_match_sequential_kernels_bitwise() {
    check("batched-vs-sequential", 40, |g| {
        let params = GemmParams::default();
        let count = 1 + g.rng.below(6);
        let threads = 1 + g.rng.below(4);
        let specs = random_specs(&mut g.rng, count);
        for scalar in [true, false] {
            let mut want: Vec<Vec<f64>> = Vec::new();
            for (m, n, k, alpha, beta, a, b, c0) in &specs {
                let mut c = c0.clone();
                if scalar {
                    level3::dgemm(*m, *n, *k, *alpha, a, b, *beta, &mut c,
                                  &params);
                } else {
                    simd::dgemm(*m, *n, *k, *alpha, a, b, *beta, &mut c,
                                &params);
                }
                want.push(c);
            }
            let mut outs: Vec<Vec<f64>> =
                specs.iter().map(|s| s.7.clone()).collect();
            let mut items: Vec<GemmItem<'_>> = specs
                .iter()
                .zip(outs.iter_mut())
                .map(|(s, c)| GemmItem {
                    m: s.0, n: s.1, k: s.2, alpha: s.3, beta: s.4,
                    a: &s.5[..], b: &s.6[..], c: &mut c[..],
                    inject: Vec::new(),
                })
                .collect();
            if scalar {
                batched::dgemm_batched(&mut items, &params, threads);
            } else {
                batched::dgemm_batched_simd(&mut items, &params, threads);
            }
            drop(items);
            for (i, (got, want)) in outs.iter().zip(&want).enumerate() {
                ensure(got == want,
                       format!("scalar={scalar} t={threads} item {i}: \
                                batched result diverged bitwise"))?;
            }
        }
        Ok(())
    });
}

/// The fused-ABFT batched driver accounts faults *per item*: striking a
/// random subset of a random batch yields exactly one detection and one
/// correction on each struck item, none anywhere else, and every output
/// still matches the naive oracle.
#[test]
fn fused_batched_driver_accounts_faults_per_item() {
    check("batched-fused-per-item-ft", 30, |g| {
        let params = GemmParams { kc: 16, ..Default::default() };
        let count = 2 + g.rng.below(5);
        let threads = 1 + g.rng.below(4);
        let specs: Vec<(usize, usize, usize, Vec<f64>, Vec<f64>)> = (0..count)
            .map(|_| {
                let m = 1 + g.rng.below(40);
                let n = 1 + g.rng.below(24);
                let k = [8usize, 16, 24, 32][g.rng.below(4)];
                let a = Matrix::random(m, k, &mut g.rng).data;
                let b = Matrix::random(k, n, &mut g.rng).data;
                (m, n, k, a, b)
            })
            .collect();
        let struck: Vec<bool> =
            (0..count).map(|_| g.rng.below(2) == 0).collect();
        let want: Vec<Vec<f64>> = specs
            .iter()
            .map(|(m, n, k, a, b)| {
                let mut c = vec![0.0; m * n];
                naive::dgemm(*m, *n, *k, 1.0, a, b, 0.0, &mut c);
                c
            })
            .collect();
        let mut outs: Vec<Vec<f64>> =
            specs.iter().map(|(m, n, ..)| vec![0.0; m * n]).collect();
        let mut items: Vec<GemmItem<'_>> = specs
            .iter()
            .zip(outs.iter_mut())
            .zip(&struck)
            .map(|(((m, n, k, a, b), c), &hit)| GemmItem {
                m: *m, n: *n, k: *k, alpha: 1.0, beta: 0.0,
                a: &a[..], b: &b[..], c: &mut c[..],
                inject: if hit {
                    vec![(0, g.rng.below(*m), g.rng.below(*n), 5e4)]
                } else {
                    Vec::new()
                },
            })
            .collect();
        let reps = batched::dgemm_batched_abft_fused_simd(&mut items,
                                                          &params, threads);
        drop(items);
        ensure(reps.len() == count, "one report per item")?;
        for (i, (rep, &hit)) in reps.iter().zip(&struck).enumerate() {
            ensure(rep.errors_detected == hit as u64,
                   format!("item {i}: wrong detection count"))?;
            ensure(rep.errors_corrected == hit as u64,
                   format!("item {i}: wrong correction count"))?;
        }
        for (i, (got, want)) in outs.iter().zip(&want).enumerate() {
            ensure(allclose(got, want, 1e-7, 1e-7),
                   format!("item {i}: output wrong after correction"))?;
        }
        Ok(())
    });
}

/// End to end through the public API: a burst of small same-shape DGEMMs
/// under a stride-1 campaign fuses through the batched fused-ABFT kernel
/// and the ledger stays exactly balanced — every armed fault detected
/// and corrected, fused completions attributed to the batched kernel,
/// and every fused batch carrying at least two items.
#[test]
fn fused_server_batches_balance_the_campaign_ledger() {
    let campaign = CampaignConfig {
        stride: 1,
        rate_per_min: f64::INFINITY,
        ..Default::default()
    };
    let router = Router::native_only(Profile::default(), Backend::NativeSimd)
        .with_campaign(campaign);
    // one worker: the large head-of-queue DTRSV (a different batch key)
    // pins it while the small GEMMs pile into one kernel-keyed group
    let server = Server::start(router, FtPolicy::Hybrid, 1, None, 0);
    let handle = server.handle();
    let mut rng = Rng::new(0x5BA7);
    let big = 1536;
    let l = Matrix::random_lower_triangular(big, &mut rng);
    let mut rxs = vec![handle.submit(BlasRequest::Dtrsv {
        a: l,
        b: rng.normal_vec(big),
    })];
    let n = 24; // below the batch dim ceiling: plans serial, fuses
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let mut want = vec![0.0; n * n];
    naive::dgemm(n, n, n, 1.0, &a.data, &b.data, 0.0, &mut want);
    let smalls = 12;
    for _ in 0..smalls {
        rxs.push(handle.submit(BlasRequest::Dgemm {
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        }));
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.ft.errors_detected, 1,
                   "stride-1 campaign strikes every protected request");
        assert_eq!(resp.ft.errors_corrected, 1);
        if i > 0 {
            let got = resp.result.as_matrix().unwrap();
            assert!(allclose(&got.data, &want, 1e-7, 1e-7),
                    "struck small GEMM {i} must still be corrected");
        }
    }
    let m = server.shutdown();
    let total = (smalls + 1) as u64;
    assert_eq!(m.completed, total);
    assert_eq!(m.failed, 0);
    // the fusion fast path fired, and its counters are self-consistent:
    // every fused batch carries at least two items
    assert!(m.batches_fused >= 1, "no batch fused");
    assert!(m.items_fused >= 2 * m.batches_fused,
            "a fused batch carried fewer than 2 items: {} batches, {} items",
            m.batches_fused, m.items_fused);
    let k = &m.kernels["dgemm/batched-abft-fused-simd"];
    assert!(k.completed >= 2,
            "fused completions land under the batched kernel's name");
    assert!(k.max_items_per_batch >= 2);
    assert!(k.max_items_per_batch <= m.items_fused);
    assert_eq!(k.errors_escaped, 0);
    // per-kernel completions roll up exactly across fused + per-item paths
    let ledger_total: u64 = m.kernels.values().map(|k| k.completed).sum();
    assert_eq!(ledger_total, total);
    // exact campaign balance: armed == detected == corrected, none escape
    assert_eq!(m.errors_injected, total);
    assert_eq!(m.errors_detected, total);
    assert_eq!(m.errors_corrected, total);
    assert_eq!(m.errors_escaped, 0);
    assert_eq!(m.injection_mode, "campaign");
}
