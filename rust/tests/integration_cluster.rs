//! Integration tests over the sharded serving tier: rendezvous routing
//! across shards, exact per-shard ledger merges, queue-depth admission
//! control under saturating traffic, and cluster-wide fault injection.

use ftblas::config::Profile;
use ftblas::coordinator::cluster::{Cluster, ClusterConfig, Error};
use ftblas::coordinator::metrics::MetricsSnapshot;
use ftblas::coordinator::request::{Backend, BlasRequest};
use ftblas::coordinator::router::Router;
use ftblas::coordinator::trace::{self, Burst, TraceConfig};
use ftblas::ft::injector::InjectorConfig;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::matrix::{allclose, Matrix};
use ftblas::util::rng::Rng;

fn native_cluster(profile: Profile, policy: FtPolicy, shards: usize,
                  workers: usize, injection: Option<InjectorConfig>,
                  expected: usize) -> Cluster {
    let workers_per_shard = workers;
    let router = Router::native_only(profile, Backend::NativeTuned);
    Cluster::start(router, policy, ClusterConfig {
        shards,
        workers_per_shard,
        injection,
        expected_requests: expected,
    })
}

/// A mixed trace on a two-shard cluster lands on both shards, each
/// kernel's traffic stays on exactly one shard (rendezvous routing on
/// the planned kernel id), and the merged snapshot is the exact
/// aggregation of the per-shard ledgers — counters sum and the overall
/// latency summary equals the sample-weighted combination, not a
/// mean-of-shard-means.
#[test]
fn two_shard_trace_merges_ledgers_exactly() {
    let cfg = TraceConfig {
        requests: 80,
        vec_len: 2048,
        mat_dim: 48,
        ..Default::default()
    };
    let entries = trace::generate(&cfg);
    let cluster = native_cluster(Profile::default(), FtPolicy::Hybrid, 2, 2,
                                 None, entries.len());
    let handle = cluster.handle();
    let rxs: Vec<_> = entries
        .iter()
        .map(|e| handle.submit(e.request.clone()).expect("unbounded admission"))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let shards = cluster.shard_metrics();
    let merged = cluster.shutdown();
    assert_eq!(shards.len(), 2);
    assert!(shards.iter().all(|s| s.completed > 0),
            "the trace must drive both shards: {:?}",
            shards.iter().map(|s| s.completed).collect::<Vec<_>>());
    // counters aggregate exactly
    assert_eq!(merged.completed, 80);
    assert_eq!(merged.completed,
               shards.iter().map(|s| s.completed).sum::<u64>());
    assert_eq!(merged.failed, 0);
    assert_eq!(merged.shed, 0);
    // kernel-keyed routing: each executed kernel lives on exactly one
    // shard, and its merged ledger equals that shard's
    for (name, k) in &merged.kernels {
        let owners: Vec<u64> = shards
            .iter()
            .filter_map(|s| s.kernels.get(name).map(|k| k.completed))
            .collect();
        assert_eq!(owners.len(), 1,
                   "{name}: kernel traffic split across shards");
        assert_eq!(owners[0], k.completed, "{name}: merge drifted");
    }
    // the merged overall summary is computed from all samples: its mean
    // must equal the completion-weighted combination of shard means
    let weighted: f64 = shards
        .iter()
        .map(|s| s.e2e_overall.mean * s.e2e_overall.n as f64)
        .sum::<f64>() / merged.completed as f64;
    assert_eq!(merged.e2e_overall.n as u64, merged.completed);
    assert!((merged.overall_e2e().mean - weighted).abs() < 1e-12,
            "merged mean {} != exact weighted mean {weighted}",
            merged.overall_e2e().mean);
    // planning happened once per distinct shape in the shared cache
    assert_eq!(merged.plan_cache_hits + merged.plan_cache_misses, 80);
    assert!(merged.plan_cache_hits > merged.plan_cache_misses);
    assert!(shards.iter().all(|s| s.plan_cache_misses == 0),
            "shard-local caches must be bypassed in cluster mode");
}

/// Saturation: a bursty all-DGEMM trace against a depth-1 watermark and
/// one worker per shard. Excess submissions come back as the typed
/// `Error::Overloaded` (never silent queue growth — the queue-depth
/// watermark holds), accepted requests still complete with correct
/// results, and the merged snapshot accounts for every shed.
#[test]
fn saturating_trace_sheds_typed_overloads() {
    let n = 192;
    let profile = Profile::default().with_admission_depth(1);
    let cluster = native_cluster(profile, FtPolicy::Hybrid, 2, 1, None, 0);
    let handle = cluster.handle();
    let mut rng = Rng::new(0x0C1);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let mut want = vec![0.0; n * n];
    ftblas::blas::naive::dgemm(n, n, n, 1.0, &a.data, &b.data, 0.0, &mut want);
    // a burst-shaped submission storm: every request identical, so all
    // of them route to one shard and pile onto its depth-1 queue far
    // faster than a single worker drains ~30ms kernels
    let mut accepted = Vec::new();
    let mut shed = 0u64;
    for _ in 0..16 {
        let req = BlasRequest::Dgemm {
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        };
        match handle.submit(req) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                assert!(matches!(e, Error::Overloaded { limit: 1, .. }),
                        "unexpected rejection: {e}");
                shed += 1;
            }
        }
    }
    assert!(!accepted.is_empty(), "the first submission is always admitted");
    assert!(shed >= 1, "a saturating storm must shed");
    for rx in accepted {
        let resp = rx.recv().unwrap().unwrap();
        let got = resp.result.as_matrix().unwrap();
        assert!(allclose(&got.data, &want, 1e-7, 1e-7),
                "accepted request returned a wrong result");
    }
    let merged = cluster.shutdown();
    assert_eq!(merged.shed, shed, "every rejection lands in the ledger");
    assert_eq!(merged.completed + merged.shed, 16);
    assert_eq!(merged.failed, 0);
    assert!(merged.max_queue_depth <= 1,
            "queue grew past the admission watermark: {}",
            merged.max_queue_depth);
}

/// Cluster-wide injection: per-shard injectors fire independently and
/// the merged FT counters balance (every injected fault detected and
/// corrected), with per-kernel attribution intact — the per-stream
/// fault-accounting shape, merged at the end.
#[test]
fn injection_merges_ft_counters_across_shards() {
    let inj = InjectorConfig { count: 8, ..Default::default() };
    let cluster = native_cluster(Profile::default(), FtPolicy::Hybrid, 2, 2,
                                 Some(inj), 48);
    let handle = cluster.handle();
    let mut rng = Rng::new(0x1F7);
    let l = Matrix::random_lower_triangular(64, &mut rng);
    let mut rxs = Vec::new();
    let mut oracle = Vec::new();
    for i in 0..48 {
        if i % 2 == 0 {
            let b = rng.normal_vec(64);
            let mut want = b.clone();
            ftblas::blas::naive::dtrsv_lower(64, &l.data, &mut want);
            oracle.push(Some(want));
            rxs.push(handle.submit(BlasRequest::Dtrsv { a: l.clone(), b })
                .unwrap());
        } else {
            oracle.push(None);
            rxs.push(handle
                .submit(BlasRequest::Ddot {
                    x: rng.normal_vec(1024),
                    y: rng.normal_vec(1024),
                })
                .unwrap());
        }
    }
    for (rx, want) in rxs.into_iter().zip(oracle) {
        let resp = rx.recv().unwrap().unwrap();
        if let Some(want) = want {
            let got = resp.result.as_vector().unwrap();
            assert!(allclose(got, &want, 1e-8, 1e-8));
        }
    }
    let merged = cluster.shutdown();
    assert_eq!(merged.completed, 48);
    assert!(merged.errors_injected >= 1, "planned faults should fire");
    assert_eq!(merged.errors_detected, merged.errors_injected);
    assert_eq!(merged.errors_corrected, merged.errors_detected);
    // attribution: FT counters sit on the kernels that ran protected
    let ft_total: u64 = merged
        .kernels
        .values()
        .map(|k| k.errors_detected)
        .sum();
    assert_eq!(ft_total, merged.errors_detected);
}

/// The bursty trace overlay drives shedding through the real pipeline:
/// with plain Poisson pacing ignored (submissions are immediate) the
/// burst just documents intent, so this test instead checks the merged
/// SLO view — burns are counted per kernel and the totals roll up.
#[test]
fn slo_burns_roll_up_in_the_merged_ledger() {
    // impossible 1ns targets: every completion burns
    let slo = ftblas::config::SloTable::by_level(1e-9, 1e-9, 1e-9);
    let profile = Profile::default().with_slo(slo);
    let cluster = native_cluster(profile, FtPolicy::None, 2, 2, None, 0);
    let handle = cluster.handle();
    let cfg = TraceConfig {
        requests: 24,
        vec_len: 1024,
        mat_dim: 32,
        burst: Some(Burst::default()),
        seed: 0x510,
        ..Default::default()
    };
    let rxs: Vec<_> = trace::generate(&cfg)
        .iter()
        .map(|e| handle.submit(e.request.clone()).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let shards = cluster.shard_metrics();
    let merged = cluster.shutdown();
    assert_eq!(merged.completed, 24);
    assert_eq!(merged.slo_burns(), 24, "1ns targets must all burn");
    assert_eq!(merged.slo_burns(),
               shards.iter().map(MetricsSnapshot::slo_burns).sum::<u64>());
    for k in merged.kernels.values() {
        assert_eq!(k.slo_burns, k.completed, "{}: burns != completions",
                   k.routine);
        assert!(k.slo_target > 0.0);
    }
}
