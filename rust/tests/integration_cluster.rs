//! Integration tests over the sharded serving tier: rendezvous routing
//! across shards, exact per-shard ledger merges, queue-depth admission
//! control under saturating traffic, cluster-wide fault injection, and
//! the elastic grow → drain → shrink cycle (manual and autoscaled).

use ftblas::config::Profile;
use ftblas::coordinator::autoscale::ScalingConfig;
use ftblas::coordinator::cluster::{Cluster, ClusterConfig, Error,
                                   RetryPolicy};
use ftblas::coordinator::metrics::MetricsSnapshot;
use ftblas::coordinator::request::{Backend, BlasRequest};
use ftblas::coordinator::router::Router;
use ftblas::coordinator::trace::{self, Burst, TraceConfig};
use ftblas::ft::injector::{CampaignConfig, CampaignTarget, InjectorConfig};
use ftblas::ft::policy::FtPolicy;
use ftblas::util::matrix::{allclose, Matrix};
use ftblas::util::rng::Rng;

fn native_cluster(profile: Profile, policy: FtPolicy, shards: usize,
                  workers: usize, injection: Option<InjectorConfig>,
                  expected: usize) -> Cluster {
    let workers_per_shard = workers;
    let router = Router::native_only(profile, Backend::NativeTuned);
    Cluster::start(router, policy, ClusterConfig {
        shards,
        workers_per_shard,
        injection,
        expected_requests: expected,
        campaign: None,
        autoscale: None,
    })
}

/// A mixed trace on a two-shard cluster lands on both shards, each
/// kernel's traffic stays on exactly one shard (rendezvous routing on
/// the planned kernel id), and the merged snapshot is the exact
/// aggregation of the per-shard ledgers — counters sum and the overall
/// latency summary equals the sample-weighted combination, not a
/// mean-of-shard-means.
#[test]
fn two_shard_trace_merges_ledgers_exactly() {
    let cfg = TraceConfig {
        requests: 80,
        vec_len: 2048,
        mat_dim: 48,
        ..Default::default()
    };
    let entries = trace::generate(&cfg);
    let cluster = native_cluster(Profile::default(), FtPolicy::Hybrid, 2, 2,
                                 None, entries.len());
    let handle = cluster.handle();
    let rxs: Vec<_> = entries
        .iter()
        .map(|e| handle.submit(e.request.clone()).expect("unbounded admission"))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let shards = cluster.shard_metrics();
    let merged = cluster.shutdown();
    assert_eq!(shards.len(), 2);
    assert!(shards.iter().all(|s| s.completed > 0),
            "the trace must drive both shards: {:?}",
            shards.iter().map(|s| s.completed).collect::<Vec<_>>());
    // counters aggregate exactly
    assert_eq!(merged.completed, 80);
    assert_eq!(merged.completed,
               shards.iter().map(|s| s.completed).sum::<u64>());
    assert_eq!(merged.failed, 0);
    assert_eq!(merged.shed, 0);
    // kernel-keyed routing: each executed kernel lives on exactly one
    // shard, and its merged ledger equals that shard's
    for (name, k) in &merged.kernels {
        let owners: Vec<u64> = shards
            .iter()
            .filter_map(|s| s.kernels.get(name).map(|k| k.completed))
            .collect();
        assert_eq!(owners.len(), 1,
                   "{name}: kernel traffic split across shards");
        assert_eq!(owners[0], k.completed, "{name}: merge drifted");
    }
    // the merged overall summary is computed from all samples: its mean
    // must equal the completion-weighted combination of shard means
    let weighted: f64 = shards
        .iter()
        .map(|s| s.e2e_overall.mean * s.e2e_overall.n as f64)
        .sum::<f64>() / merged.completed as f64;
    assert_eq!(merged.e2e_overall.n as u64, merged.completed);
    assert!((merged.overall_e2e().mean - weighted).abs() < 1e-12,
            "merged mean {} != exact weighted mean {weighted}",
            merged.overall_e2e().mean);
    // planning happened once per distinct shape in the shared cache
    assert_eq!(merged.plan_cache_hits + merged.plan_cache_misses, 80);
    assert!(merged.plan_cache_hits > merged.plan_cache_misses);
    assert!(shards.iter().all(|s| s.plan_cache_misses == 0),
            "shard-local caches must be bypassed in cluster mode");
}

/// Saturation: a bursty all-DGEMM trace against a depth-1 watermark and
/// one worker per shard. Excess submissions come back as the typed
/// `Error::Overloaded` (never silent queue growth — the queue-depth
/// watermark holds), accepted requests still complete with correct
/// results, and the merged snapshot accounts for every shed.
#[test]
fn saturating_trace_sheds_typed_overloads() {
    let n = 192;
    let profile = Profile::default().with_admission_depth(1);
    let cluster = native_cluster(profile, FtPolicy::Hybrid, 2, 1, None, 0);
    let handle = cluster.handle();
    let mut rng = Rng::new(0x0C1);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let mut want = vec![0.0; n * n];
    ftblas::blas::naive::dgemm(n, n, n, 1.0, &a.data, &b.data, 0.0, &mut want);
    // a burst-shaped submission storm: every request identical, so all
    // of them route to one shard and pile onto its depth-1 queue far
    // faster than a single worker drains ~30ms kernels
    let mut accepted = Vec::new();
    let mut shed = 0u64;
    for _ in 0..16 {
        let req = BlasRequest::Dgemm {
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        };
        match handle.submit(req) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                assert!(matches!(e, Error::Overloaded { limit: 1, .. }),
                        "unexpected rejection: {e}");
                shed += 1;
            }
        }
    }
    assert!(!accepted.is_empty(), "the first submission is always admitted");
    assert!(shed >= 1, "a saturating storm must shed");
    for rx in accepted {
        let resp = rx.recv().unwrap().unwrap();
        let got = resp.result.as_matrix().unwrap();
        assert!(allclose(&got.data, &want, 1e-7, 1e-7),
                "accepted request returned a wrong result");
    }
    let merged = cluster.shutdown();
    assert_eq!(merged.shed, shed, "every rejection lands in the ledger");
    assert_eq!(merged.completed + merged.shed, 16);
    assert_eq!(merged.failed, 0);
    assert!(merged.max_queue_depth <= 1,
            "queue grew past the admission watermark: {}",
            merged.max_queue_depth);
}

/// Cluster-wide injection: per-shard injectors fire independently and
/// the merged FT counters balance (every injected fault detected and
/// corrected), with per-kernel attribution intact — the per-stream
/// fault-accounting shape, merged at the end.
#[test]
fn injection_merges_ft_counters_across_shards() {
    let inj = InjectorConfig { count: 8, ..Default::default() };
    let cluster = native_cluster(Profile::default(), FtPolicy::Hybrid, 2, 2,
                                 Some(inj), 48);
    let handle = cluster.handle();
    let mut rng = Rng::new(0x1F7);
    let l = Matrix::random_lower_triangular(64, &mut rng);
    let mut rxs = Vec::new();
    let mut oracle = Vec::new();
    for i in 0..48 {
        if i % 2 == 0 {
            let b = rng.normal_vec(64);
            let mut want = b.clone();
            ftblas::blas::naive::dtrsv_lower(64, &l.data, &mut want);
            oracle.push(Some(want));
            rxs.push(handle.submit(BlasRequest::Dtrsv { a: l.clone(), b })
                .unwrap());
        } else {
            oracle.push(None);
            rxs.push(handle
                .submit(BlasRequest::Ddot {
                    x: rng.normal_vec(1024),
                    y: rng.normal_vec(1024),
                })
                .unwrap());
        }
    }
    for (rx, want) in rxs.into_iter().zip(oracle) {
        let resp = rx.recv().unwrap().unwrap();
        if let Some(want) = want {
            let got = resp.result.as_vector().unwrap();
            assert!(allclose(got, &want, 1e-8, 1e-8));
        }
    }
    let merged = cluster.shutdown();
    assert_eq!(merged.completed, 48);
    assert!(merged.errors_injected >= 1, "planned faults should fire");
    assert_eq!(merged.errors_detected, merged.errors_injected);
    assert_eq!(merged.errors_corrected, merged.errors_detected);
    // attribution: FT counters sit on the kernels that ran protected
    let ft_total: u64 = merged
        .kernels
        .values()
        .map(|k| k.errors_detected)
        .sum();
    assert_eq!(ft_total, merged.errors_detected);
}

/// A cluster-wide injection campaign is elasticity-proof end to end:
/// shards grown mid-run inherit the campaign through the shared router
/// and fire their slice of the schedule, a shard drained mid-run
/// retires its strike outcomes exactly, and across the whole run every
/// injected fault is detected and corrected — zero escapes, zero
/// count drift between the campaign's own ledger and the merged
/// metrics.
#[test]
fn campaign_strikes_inherit_across_grow_and_survive_shrink() {
    let campaign = CampaignConfig {
        seed: 0x50AC,
        rate_per_min: f64::INFINITY, // schedule-only: no rate gating
        stride: 2,
        target: CampaignTarget::AllProtected,
        ..Default::default()
    };
    let profile = Profile::default()
        .with_shard_bounds(1, 4)
        .with_campaign(campaign);
    let cluster = native_cluster(profile, FtPolicy::Hybrid, 1, 2, None, 0);
    let handle = cluster.handle();
    // grow before the traffic lands: slots 1..=3 are mid-run spawns
    // with fresh-generation salts, so between them they own most of
    // the kernel-id key space
    handle.scale_up().unwrap();
    handle.scale_up().unwrap();
    handle.scale_up().unwrap();
    assert_eq!(handle.shard_count(), 4);
    let cfg = TraceConfig {
        requests: 120,
        vec_len: 1024,
        mat_dim: 48,
        seed: 0x7A57,
        ..Default::default()
    };
    let entries = trace::generate(&cfg);
    let rxs: Vec<_> = entries[..80]
        .iter()
        .map(|e| handle.submit(e.request.clone()).expect("unbounded"))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    // drain one mid-run shard with strikes already on its ledger: the
    // retired snapshot must carry them into the merged view
    handle.scale_down().unwrap();
    let rxs: Vec<_> = entries[80..]
        .iter()
        .map(|e| handle.submit(e.request.clone()).expect("unbounded"))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let live = cluster.shard_metrics();
    let retired = cluster.retired_metrics();
    let armed = cluster.campaign().expect("campaign is live").injected();
    let merged = cluster.shutdown();
    assert_eq!(merged.completed, 120);
    assert_eq!(merged.failed, 0);
    assert_eq!(merged.injection_mode, "campaign");
    // an unbounded stride-2 campaign over 120 protected requests
    // strikes roughly half of every kernel's occurrences
    assert!(merged.errors_injected >= 20,
            "campaign barely fired: {} strikes", merged.errors_injected);
    assert_eq!(merged.errors_detected, merged.errors_injected,
               "no count drift");
    assert_eq!(merged.errors_corrected, merged.errors_detected);
    assert_eq!(merged.errors_escaped, 0, "nothing may escape");
    assert_eq!(merged.errors_injected, armed,
               "ledger and campaign agree exactly");
    // inheritance: the mid-run shards (live slots >= 1 plus the one
    // retired) took traffic and fired their slice of the schedule
    assert_eq!(live.len(), 3);
    assert_eq!(retired.len(), 1);
    let midrun_injected: u64 = live[1..]
        .iter()
        .chain(&retired)
        .map(|s| s.errors_injected)
        .sum();
    let midrun_completed: u64 = live[1..]
        .iter()
        .chain(&retired)
        .map(|s| s.completed)
        .sum();
    assert!(midrun_completed > 0, "grown shards must take traffic");
    assert!(midrun_injected > 0,
            "shards spawned mid-run must inherit campaign strikes");
}

/// The elastic cycle, driven deterministically (no controller thread):
/// a bursty trace is pushed through grow → drain → shrink, and the
/// merged ledger accounts for every request exactly — including the
/// completions of the shard that was drained mid-run. Zero responses
/// are lost across the scale events.
#[test]
fn elastic_grow_drain_shrink_loses_no_responses() {
    let profile = Profile::default().with_shard_bounds(1, 3);
    let cluster = native_cluster(profile, FtPolicy::Hybrid, 1, 2, None, 0);
    let handle = cluster.handle();
    let cfg = TraceConfig {
        requests: 90,
        vec_len: 2048,
        mat_dim: 48,
        burst: Some(Burst::default()),
        ..Default::default()
    };
    let entries = trace::generate(&cfg);
    let mut rxs = Vec::new();
    // phase 1: one shard takes the first third of the trace
    for e in &entries[..30] {
        rxs.push(handle.submit(e.request.clone()).expect("unbounded"));
    }
    // grow twice mid-stream: new shards join with fresh salts and the
    // survivors keep their queues
    assert_eq!(handle.scale_up().unwrap(), 2);
    assert_eq!(handle.scale_up().unwrap(), 3);
    assert_eq!(handle.shard_count(), 3);
    for e in &entries[30..60] {
        rxs.push(handle.submit(e.request.clone()).expect("unbounded"));
    }
    // shrink immediately, with the last slice's requests still queued:
    // scale_down must unroute the victim, drain it to completion, and
    // retire its ledger — no queued response may be dropped
    assert_eq!(handle.scale_down().unwrap(), 2);
    assert_eq!(handle.shard_count(), 2);
    for e in &entries[60..] {
        rxs.push(handle.submit(e.request.clone()).expect("unbounded"));
    }
    for rx in rxs {
        rx.recv().expect("response channel must survive scaling")
            .expect("request must execute cleanly");
    }
    let live = cluster.shard_metrics();
    let retired = cluster.retired_metrics();
    assert_eq!(live.len(), 2);
    assert_eq!(retired.len(), 1, "one shard was drained and retired");
    let merged = cluster.shutdown();
    // exact accounting across the scale events: live + retired ledgers
    // cover all 90 requests, with no sheds, failures, or losses
    assert_eq!(merged.completed, 90);
    assert_eq!(merged.failed, 0);
    assert_eq!(merged.shed, 0);
    let live_total: u64 = live.iter().map(|s| s.completed).sum();
    assert_eq!(live_total + retired[0].completed, 90,
               "every completion is attributed to a live or retired ledger");
    assert_eq!(merged.scale_ups, 2);
    assert_eq!(merged.scale_downs, 1);
    assert!(merged.keys_migrated > 0, "scale events must migrate keys");
    // the merged overall summary counts every sample exactly once
    assert_eq!(merged.overall_e2e().n as u64, 90);
    // plans resolve once per shape in the shared cache, sized across
    // the whole run regardless of topology changes
    assert_eq!(merged.plan_cache_hits + merged.plan_cache_misses, 90);
}

/// The autoscaling controller closes the loop end to end: a slow,
/// saturating workload on a 1-worker floor shard must trigger a
/// scale-up; draining the backlog and going calm must trigger the
/// scale-down. Bounded polling keeps the test robust on slow CI
/// machines.
#[test]
fn autoscaler_grows_under_pressure_and_shrinks_when_calm() {
    let n = 160;
    // a small batch window keeps the backlog visibly deep (a drain
    // removes at most 4 jobs from the pending count), and the 64-deep
    // watermark sets grow_depth at 32 — well under the 48-job pile
    let profile = Profile::default()
        .with_shard_bounds(1, 2)
        .with_max_batch(4)
        .with_admission_depth(64);
    let scfg = ScalingConfig::from_profile(&profile)
        .with_interval(std::time::Duration::from_millis(5));
    assert!(scfg.elastic());
    let router = Router::native_only(profile, Backend::NativeTuned);
    let cluster = Cluster::start(router, FtPolicy::None, ClusterConfig {
        shards: 1,
        workers_per_shard: 1,
        injection: None,
        expected_requests: 0,
        campaign: None,
        autoscale: Some(scfg),
    });
    let handle = cluster.handle();
    let mut rng = Rng::new(0xE1A);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    // a pile of ~ms GEMMs on one worker: the queue grows well past
    // grow_depth (half the 64-deep watermark) within a few intervals,
    // and the queue-wait pushes late completions far over the 50ms L3
    // SLO target — two independent grow signals
    let mut rxs = Vec::new();
    let retry = RetryPolicy::default();
    for _ in 0..48 {
        let req = BlasRequest::Dgemm {
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        };
        let (admitted, _retries) = handle.submit_with_retry(req, &retry);
        if let Ok(rx) = admitted {
            rxs.push(rx);
        }
    }
    // the controller must react while the backlog drains
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(20);
    while handle.scale_events().0 == 0 {
        assert!(std::time::Instant::now() < deadline,
                "queue pressure never triggered a scale-up");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    // calm: the controller hands capacity back down to the floor
    while handle.shard_count() > 1 {
        assert!(std::time::Instant::now() < deadline,
                "calm tier never scaled back down");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let merged = cluster.shutdown();
    assert!(merged.scale_ups >= 1);
    assert!(merged.scale_downs >= 1);
    assert_eq!(merged.failed, 0);
    assert_eq!(merged.completed + merged.shed, 48);
}

/// `submit_with_retry` turns transient `Overloaded` sheds into
/// successes: on a depth-1, 1-worker shard a storm of identical
/// requests mostly sheds without retries, but bounded backoff rides
/// out the contention. Every admitted request completes correctly and
/// the retry count is reported to the caller.
#[test]
fn retry_backoff_rides_out_transient_sheds() {
    let n = 96;
    let profile = Profile::default().with_admission_depth(1);
    let cluster = native_cluster(profile, FtPolicy::None, 1, 1, None, 0);
    let handle = cluster.handle();
    let mut rng = Rng::new(0x5AFE);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let retry = RetryPolicy {
        attempts: 40,
        base: std::time::Duration::from_micros(200),
        cap: std::time::Duration::from_millis(5),
        jitter_seed: 7,
    };
    let mut rxs = Vec::new();
    let mut total_retries = 0u32;
    for _ in 0..8 {
        let req = BlasRequest::Dgemm {
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        };
        let (admitted, retries) = handle.submit_with_retry(req, &retry);
        total_retries += retries;
        rxs.push(admitted.expect("40 bounded retries must outlast a \
                                  depth-1 queue of ~ms kernels"));
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let merged = cluster.shutdown();
    assert_eq!(merged.completed, 8, "retries admit every request");
    assert_eq!(merged.shed as u32, total_retries,
               "every shed was ridden out by exactly one retry");
}

/// The bursty trace overlay drives shedding through the real pipeline:
/// with plain Poisson pacing ignored (submissions are immediate) the
/// burst just documents intent, so this test instead checks the merged
/// SLO view — burns are counted per kernel and the totals roll up.
#[test]
fn slo_burns_roll_up_in_the_merged_ledger() {
    // impossible 1ns targets: every completion burns
    let slo = ftblas::config::SloTable::by_level(1e-9, 1e-9, 1e-9);
    let profile = Profile::default().with_slo(slo);
    let cluster = native_cluster(profile, FtPolicy::None, 2, 2, None, 0);
    let handle = cluster.handle();
    let cfg = TraceConfig {
        requests: 24,
        vec_len: 1024,
        mat_dim: 32,
        burst: Some(Burst::default()),
        seed: 0x510,
        ..Default::default()
    };
    let rxs: Vec<_> = trace::generate(&cfg)
        .iter()
        .map(|e| handle.submit(e.request.clone()).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let shards = cluster.shard_metrics();
    let merged = cluster.shutdown();
    assert_eq!(merged.completed, 24);
    assert_eq!(merged.slo_burns(), 24, "1ns targets must all burn");
    assert_eq!(merged.slo_burns(),
               shards.iter().map(MetricsSnapshot::slo_burns).sum::<u64>());
    for k in merged.kernels.values() {
        assert_eq!(k.slo_burns, k.completed, "{}: burns != completions",
                   k.routine);
        assert!(k.slo_target > 0.0);
    }
}
