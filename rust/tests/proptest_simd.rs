//! SIMD-vs-naive numerical equivalence (ISSUE 6 satellite): every new
//! microkernel against the naive oracle across odd/remainder
//! dimensions, plus a campaign-armed strike through the checksum-fused
//! SIMD DGEMM.
//!
//! Bound discipline: the element-wise kernels (DSCAL, DAXPY) compute
//! the same per-element expression as the oracle — at most one FMA
//! contraction apart — so they are held to a strict <= 4 ULP
//! per-element bound. The reductions (DDOT, DNRM2) and the GEBP DGEMM
//! re-associate the sum across lanes and tiles, so they are held to a
//! magnitude-scaled envelope instead: an ULP bound on a re-associated
//! sum is not meaningful under cancellation.

use ftblas::blas::level3::GemmParams;
use ftblas::blas::{naive, simd};
use ftblas::coordinator::registry::{KernelRegistry, Scheme};
use ftblas::ft::abft_fused::Strike;
use ftblas::ft::injector::{CampaignConfig, CampaignTarget,
                           InjectionCampaign};
use ftblas::util::check::{check, ensure};
use ftblas::util::matrix::{allclose, Matrix};

/// Distance in units-in-the-last-place between two finite doubles,
/// via the monotone mapping of the IEEE-754 bit patterns onto a signed
/// line (negative floats mirror below zero).
fn ulp_dist(a: f64, b: f64) -> u64 {
    fn key(f: f64) -> i64 {
        let i = f.to_bits() as i64;
        if i < 0 { i64::MIN - i } else { i }
    }
    if a == b {
        return 0; // covers +0.0 vs -0.0
    }
    key(a).abs_diff(key(b))
}

/// Dimensions that exercise every remainder path of the wide-lane
/// loops: below one lane, straddling the 4-lane step, straddling the
/// 16-element unrolled step, and around the prefetch distance.
const EDGE_DIMS: &[usize] =
    &[1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 63, 64, 65, 127, 129];

#[test]
fn dscal_within_4_ulp_of_naive() {
    check("simd-dscal-ulp", 30, |g| {
        let n = if g.case < EDGE_DIMS.len() {
            EDGE_DIMS[g.case]
        } else {
            g.dim(1, 400)
        };
        let alpha = g.rng.range(-3.0, 3.0);
        let x0 = g.rng.normal_vec(n);
        let mut want = x0.clone();
        naive::dscal(alpha, &mut want);
        let mut got = x0.clone();
        simd::dscal(alpha, &mut got);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            let d = ulp_dist(*a, *b);
            ensure(d <= 4, format!("dscal n={n} [{i}]: {a} vs {b} ({d} ulp)"))?;
        }
        Ok(())
    });
}

#[test]
fn daxpy_within_4_ulp_of_naive() {
    check("simd-daxpy-ulp", 30, |g| {
        let n = if g.case < EDGE_DIMS.len() {
            EDGE_DIMS[g.case]
        } else {
            g.dim(1, 400)
        };
        let alpha = g.rng.range(-3.0, 3.0);
        let x = g.rng.normal_vec(n);
        let y0 = g.rng.normal_vec(n);
        let mut want = y0.clone();
        naive::daxpy(alpha, &x, &mut want);
        let mut got = y0.clone();
        simd::daxpy(alpha, &x, &mut got);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            let d = ulp_dist(*a, *b);
            ensure(d <= 4, format!("daxpy n={n} [{i}]: {a} vs {b} ({d} ulp)"))?;
        }
        Ok(())
    });
}

#[test]
fn ddot_and_dnrm2_match_naive_within_envelope() {
    check("simd-reductions", 30, |g| {
        let n = if g.case < EDGE_DIMS.len() {
            EDGE_DIMS[g.case]
        } else {
            g.dim(1, 3000)
        };
        let x = g.rng.normal_vec(n);
        let y = g.rng.normal_vec(n);
        // envelope scaled by the magnitude actually summed, so the
        // bound stays meaningful when the signed dot cancels
        let mag: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        let got = simd::ddot(&x, &y);
        let want = naive::ddot(&x, &y);
        ensure((got - want).abs() <= 1e-13 * (1.0 + mag),
               format!("ddot n={n}: {got} vs {want}"))?;
        let got = simd::dnrm2(&x);
        let want = naive::dnrm2(&x);
        ensure((got - want).abs() <= 1e-12 * (1.0 + want),
               format!("dnrm2 n={n}: {got} vs {want}"))
    });
}

#[test]
fn dnrm2_overflow_falls_back_like_tuned() {
    let x = vec![1e300; 33];
    let got = simd::dnrm2(&x);
    let want = naive::dnrm2(&x);
    assert!(got.is_finite(), "simd dnrm2 overflowed: {got}");
    assert!((got - want).abs() <= 1e-9 * want, "{got} vs {want}");
}

#[test]
fn dgemm_matches_naive_across_odd_shapes() {
    check("simd-gemm", 20, |g| {
        // shapes straddle the 8x4 micro-tile and the kc/mc/nc blocks
        let m = g.dim(1, 70);
        let n = g.dim(1, 50);
        let k = g.dim(1, 90);
        let alpha = g.rng.range(-2.0, 2.0);
        let beta = g.rng.range(-1.0, 1.0);
        let params = GemmParams { kc: 16, mc: 24, nc: 20,
                                  ..Default::default() };
        let a = Matrix::random(m, k, &mut g.rng);
        let b = Matrix::random(k, n, &mut g.rng);
        let c0 = Matrix::random(m, n, &mut g.rng);
        let mut want = c0.data.clone();
        naive::dgemm(m, n, k, alpha, &a.data, &b.data, beta, &mut want);
        let mut got = c0.data.clone();
        simd::dgemm(m, n, k, alpha, &a.data, &b.data, beta, &mut got,
                    &params);
        ensure(allclose(&got, &want, 1e-10, 1e-10),
               format!("simd dgemm wrong at {m}x{n}x{k}"))
    });
}

/// The checksum-fused SIMD DGEMM detects and corrects a strike armed
/// through the `ft/injector.rs` campaign machinery — the same path the
/// soak harness drives — not a hand-placed fault.
#[test]
fn fused_simd_dgemm_corrects_campaign_strike() {
    let reg = KernelRegistry::global();
    let fused = reg
        .find("dgemm/abft-fused-simd")
        .expect("fused SIMD dgemm must be registered");
    let id = reg.id_of(fused).unwrap();
    // stride 1 + unbounded rate: every eligible execution is a strike,
    // so the test is deterministic
    let campaign = InjectionCampaign::new(CampaignConfig {
        stride: 1,
        rate_per_min: f64::INFINITY,
        target: CampaignTarget::Fused,
        ..Default::default()
    });
    let (m, n, k) = (48, 40, 64);
    let params = GemmParams { kc: 16, ..Default::default() };
    let nsteps = k.div_ceil(params.kc);
    let mut rng = ftblas::util::rng::Rng::new(0x51D);
    let a = Matrix::random(m, k, &mut rng);
    let b = Matrix::random(k, n, &mut rng);
    let mut want = vec![0.0; m * n];
    naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
    for round in 0..8 {
        let fault = campaign
            .arm(id, Scheme::AbftFused, m)
            .expect("stride-1 unbounded campaign must strike every arm");
        let strike: Strike =
            (fault.step % nsteps, fault.i % m, fault.j % n, fault.delta);
        let mut c = vec![0.0; m * n];
        let rep = simd::dgemm_abft_fused(m, n, k, 1.0, &a.data, &b.data,
                                         0.0, &mut c, &params, &[strike]);
        assert_eq!(rep.errors_detected, 1,
                   "round {round}: strike {strike:?} not detected");
        assert_eq!(rep.errors_corrected, 1,
                   "round {round}: strike {strike:?} not corrected");
        assert!(allclose(&c, &want, 1e-8, 1e-8),
                "round {round}: corrected result wrong");
    }
    assert_eq!(campaign.injected(), 8);
}
