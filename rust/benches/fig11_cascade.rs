//! Regenerates paper experiment `fig11` (see DESIGN.md §5).
//! Run: `cargo bench --bench fig11_cascade` (add -- --quick for a fast pass).
use ftblas::bench::{self, BenchCtx};
use ftblas::config::Profile;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("FTBLAS_BENCH_QUICK").is_ok();
    let mut ctx = BenchCtx::with_artifacts(Profile::skylake_sim(), quick);
    bench::run("fig11", &mut ctx)
}
