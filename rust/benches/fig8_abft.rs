//! Regenerates paper Fig. 8 (a and b): fused vs unfused ABFT DGEMM.
//! Run: `cargo bench --bench fig8_abft`.
use ftblas::bench::{self, BenchCtx};
use ftblas::config::Profile;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("FTBLAS_BENCH_QUICK").is_ok();
    let mut ctx = BenchCtx::with_artifacts(Profile::skylake_sim(), quick);
    bench::run("fig8a", &mut ctx)?;
    bench::run("fig8b", &mut ctx)
}
