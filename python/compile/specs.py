"""Artifact specifications: every routine x variant x shape lowered by aot.py.

Each spec names a jax-traceable builder from model.py, its example input
shapes (f64 everywhere), and metadata the Rust artifact registry uses for
routing (routine name, FT variant, dimensions, tuning parameters).
"""

import functools

import jax.numpy as jnp

from . import model

F64 = jnp.float64


class Spec:
    def __init__(self, name, fn, inputs, routine, variant, meta=None):
        self.name = name
        self.fn = fn  # callable taking jax arrays, returns array or tuple
        self.inputs = inputs  # list of shape tuples
        self.routine = routine
        self.variant = variant  # ori | dmr | abft | abft_rankk | ft
        self.meta = dict(meta or {})

    def example_args(self):
        import jax

        return [jax.ShapeDtypeStruct(s, F64) for s in self.inputs]


def _wrap_tuple(fn):
    """Ensure the lowered function returns a flat tuple (stable interchange)."""

    @functools.wraps(fn)
    def wrapped(*args):
        out = fn(*args)
        if isinstance(out, (tuple, list)):
            flat = []
            for o in out:
                flat.append(o)
            return tuple(flat)
        return (out,)

    return wrapped


def build_specs(profile="skylake_sim"):
    """The full artifact set. `profile` selects tuning parameters
    (DESIGN.md substitution #4: two machines -> two tuning profiles)."""
    if profile == "skylake_sim":
        l3 = dict(bm=64, bn=64, bk=64)
        gv = dict(bm=64, bn=256)
        blk = 1024
        trsm_panel = 16
    elif profile == "cascade_sim":
        l3 = dict(bm=32, bn=128, bk=64)
        gv = dict(bm=32, bn=128)
        blk = 2048
        trsm_panel = 32
    else:
        raise ValueError(profile)

    S = []
    add = S.append

    # ----------------------------------------------------------- Level 1
    for n in (65536, 262144):
        add(Spec(f"dscal_ori_n{n}",
                 _wrap_tuple(lambda a, x: model.dscal(a, x, block=blk)),
                 [(), (n,)], "dscal", "ori", {"n": n, "block": blk}))
        add(Spec(f"dscal_dmr_n{n}",
                 _wrap_tuple(lambda a, x, i: model.dscal_dmr(a, x, i, block=blk)),
                 [(), (n,), (3,)], "dscal", "dmr", {"n": n, "block": blk}))
        add(Spec(f"daxpy_ori_n{n}",
                 _wrap_tuple(lambda a, x, y: model.daxpy(a, x, y, block=blk)),
                 [(), (n,), (n,)], "daxpy", "ori", {"n": n, "block": blk}))
        add(Spec(f"daxpy_dmr_n{n}",
                 _wrap_tuple(lambda a, x, y, i: model.daxpy_dmr(a, x, y, i, block=blk)),
                 [(), (n,), (n,), (3,)], "daxpy", "dmr", {"n": n, "block": blk}))
        add(Spec(f"ddot_ori_n{n}",
                 _wrap_tuple(lambda x, y: model.ddot(x, y, block=blk)),
                 [(n,), (n,)], "ddot", "ori", {"n": n, "block": blk}))
        add(Spec(f"ddot_dmr_n{n}",
                 _wrap_tuple(lambda x, y, i: model.ddot_dmr(x, y, i, block=blk)),
                 [(n,), (n,), (3,)], "ddot", "dmr", {"n": n, "block": blk}))
        add(Spec(f"dnrm2_ori_n{n}",
                 _wrap_tuple(lambda x: model.dnrm2(x, block=blk)),
                 [(n,)], "dnrm2", "ori", {"n": n, "block": blk}))
        add(Spec(f"dnrm2_dmr_n{n}",
                 _wrap_tuple(lambda x, i: model.dnrm2_dmr(x, i, block=blk)),
                 [(n,), (3,)], "dnrm2", "dmr", {"n": n, "block": blk}))
    add(Spec("dasum_ori_n65536",
             _wrap_tuple(lambda x: model.dasum(x, block=blk)),
             [(65536,)], "dasum", "ori", {"n": 65536, "block": blk}))
    add(Spec("drot_ori_n65536",
             _wrap_tuple(lambda x, y, c, s: model.drot(x, y, c, s, block=blk)),
             [(65536,), (65536,), (), ()], "drot", "ori",
             {"n": 65536, "block": blk}))

    # ----------------------------------------------------------- Level 2
    for n in (256, 512, 1024):
        add(Spec(f"dgemv_ori_n{n}",
                 _wrap_tuple(lambda al, a, x, be, y: model.dgemv(al, a, x, be, y, **gv)),
                 [(), (n, n), (n,), (), (n,)], "dgemv", "ori",
                 {"n": n, **gv}))
        add(Spec(f"dgemv_dmr_n{n}",
                 _wrap_tuple(lambda al, a, x, be, y, i: model.dgemv_dmr(al, a, x, be, y, i, **gv)),
                 [(), (n, n), (n,), (), (n,), (4,)], "dgemv", "dmr",
                 {"n": n, **gv}))
    for n in (256, 512):
        add(Spec(f"dtrsv_ori_n{n}",
                 _wrap_tuple(lambda a, b: model.dtrsv(a, b, panel=4, bn=64)),
                 [(n, n), (n,)], "dtrsv", "ori", {"n": n, "panel": 4}))
        add(Spec(f"dtrsv_b64_n{n}",
                 _wrap_tuple(lambda a, b: model.dtrsv(a, b, panel=64, bn=64)),
                 [(n, n), (n,)], "dtrsv", "b64", {"n": n, "panel": 64}))
        add(Spec(f"dtrsv_dmr_n{n}",
                 _wrap_tuple(lambda a, b, i: model.dtrsv_dmr(a, b, i, panel=4, bn=64)),
                 [(n, n), (n,), (4,)], "dtrsv", "dmr", {"n": n, "panel": 4}))

    # ----------------------------------------------------------- Level 3
    for n in (128, 256, 512):
        add(Spec(f"dgemm_ori_n{n}",
                 _wrap_tuple(lambda al, a, b, be, c: model.dgemm(al, a, b, be, c, **l3)),
                 [(), (n, n), (n, n), (), (n, n)], "dgemm", "ori",
                 {"n": n, **l3}))
        add(Spec(f"dgemm_abft_n{n}",
                 _wrap_tuple(lambda a, b, i: model.dgemm_abft_full(a, b, i, **l3)),
                 [(n, n), (n, n), (4,)], "dgemm", "abft", {"n": n, **l3}))
    for n, kc in ((256, 64), (512, 128)):
        add(Spec(f"dgemm_abft_rankk_n{n}_kc{kc}",
                 _wrap_tuple(lambda a, b, c, i: model.dgemm_abft(a, b, c, i, **l3)),
                 [(n, kc), (kc, n), (n, n), (4,)], "dgemm", "abft_rankk",
                 {"n": n, "kc": kc, **l3}))
    for n in (256, 512):
        add(Spec(f"dtrsm_ori_n{n}",
                 _wrap_tuple(lambda a, b: model.dtrsm(a, b, panel=trsm_panel, bn=l3["bn"], bk=l3["bk"])),
                 [(n, n), (n, n)], "dtrsm", "ori",
                 {"n": n, "panel": trsm_panel}))
        add(Spec(f"dtrsm_ft_n{n}",
                 _wrap_tuple(lambda a, b, i: model.dtrsm_ft(a, b, i, panel=trsm_panel, bn=l3["bn"], bk=l3["bk"])),
                 [(n, n), (n, n), (5,)], "dtrsm", "ft",
                 {"n": n, "panel": trsm_panel}))
    n = 256
    add(Spec(f"dsymm_ori_n{n}",
             _wrap_tuple(lambda al, a, b, be, c: model.dsymm(al, a, b, be, c, **l3)),
             [(), (n, n), (n, n), (), (n, n)], "dsymm", "ori", {"n": n}))
    add(Spec(f"dsymm_abft_n{n}",
             _wrap_tuple(lambda a, b, c, i: model.dsymm_abft(a, b, c, i, **l3)),
             [(n, n), (n, n), (n, n), (4,)], "dsymm", "abft", {"n": n}))
    add(Spec(f"dtrmm_ori_n{n}",
             _wrap_tuple(lambda al, a, b: model.dtrmm(al, a, b, **l3)),
             [(), (n, n), (n, n)], "dtrmm", "ori", {"n": n}))
    add(Spec(f"dtrmm_abft_n{n}",
             _wrap_tuple(lambda a, b, i: model.dtrmm_abft(a, b, i, **l3)),
             [(n, n), (n, n), (4,)], "dtrmm", "abft", {"n": n}))
    add(Spec(f"dsyrk_ori_n{n}",
             _wrap_tuple(lambda al, a, be, c: model.dsyrk(al, a, be, c, **l3)),
             [(), (n, n), (), (n, n)], "dsyrk", "ori", {"n": n}))

    return S
