"""FT-BLAS Pallas kernel library (Layer 1).

Every kernel has a pure-jnp oracle in ref.py; pytest + hypothesis verify
them block-size- and shape-parametrically. All kernels are lowered with
interpret=True (mandatory for CPU PJRT on this image).
"""

from . import gemm, gemm_abft, gemv, level1, level1_dmr, ref  # noqa: F401
