"""Pure-jnp oracles for every kernel and routine in FT-BLAS.

These are the ground truth the Pallas kernels (and, transitively, the Rust
native kernels — which are tested against the same math) are verified
against. Everything is double precision, matching the paper's D-prefixed
routines.
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- Level 1

def dscal(alpha, x):
    return alpha * x


def daxpy(alpha, x, y):
    return alpha * x + y


def ddot(x, y):
    return jnp.dot(x, y)


def dnrm2(x):
    # Scaled to avoid overflow, like reference BLAS drivers.
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax, 1.0)
    return scale * jnp.sqrt(jnp.sum((x / scale) ** 2))


def dnrm2_unscaled(x):
    # The Pallas kernel computes the unscaled sqrt(sum of squares); overflow
    # scaling happens in the L2 driver, as in the paper's kernel split.
    return jnp.sqrt(jnp.sum(x * x))


def dasum(x):
    return jnp.sum(jnp.abs(x))


def dcopy(x):
    return x


def dswap(x, y):
    return y, x


def drot(x, y, c, s):
    return c * x + s * y, c * y - s * x


def drotm(x, y, param):
    """Modified Givens rotation; param = [flag, h11, h21, h12, h22]
    (reference-BLAS flag semantics)."""
    flag, h11, h21, h12, h22 = (param[i] for i in range(5))
    h11 = jnp.where(flag == 0.0, 1.0, h11)
    h22 = jnp.where(flag == 0.0, 1.0, h22)
    h12 = jnp.where(flag == 1.0, 1.0, h12)
    h21 = jnp.where(flag == 1.0, -1.0, h21)
    ox = h11 * x + h12 * y
    oy = h21 * x + h22 * y
    ident = flag == -2.0
    return jnp.where(ident, x, ox), jnp.where(ident, y, oy)


def idamax(x):
    return jnp.argmax(jnp.abs(x))


# ---------------------------------------------------------------- Level 2

def dgemv(alpha, a, x, beta, y):
    return alpha * (a @ x) + beta * y


def dgemv_t(alpha, a, x, beta, y):
    return alpha * (a.T @ x) + beta * y


def dger(alpha, x, y, a):
    return a + alpha * jnp.outer(x, y)


def dtrmv_lower(a, x):
    return jnp.tril(a) @ x


def dsymv_lower(alpha, a, x, beta, y):
    full = jnp.tril(a) + jnp.tril(a, -1).T
    return alpha * (full @ x) + beta * y


def dtrsv_lower(a, b):
    """Solve L x = b with L = tril(a), non-unit diagonal."""
    a, b = jnp.asarray(a), jnp.asarray(b)
    n = b.shape[0]
    low = jnp.tril(a)

    def body(i, x):
        partial = jnp.dot(jnp.where(jnp.arange(n) < i, low[i, :], 0.0), x)
        return x.at[i].set((b[i] - partial) / low[i, i])

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


# ---------------------------------------------------------------- Level 3

def dgemm(alpha, a, b, beta, c):
    return alpha * (a @ b) + beta * c


def dsymm_lower(alpha, a, b, beta, c):
    full = jnp.tril(a) + jnp.tril(a, -1).T
    return alpha * (full @ b) + beta * c


def dtrmm_lower(alpha, a, b):
    return alpha * (jnp.tril(a) @ b)


def dsyrk_lower(alpha, a, beta, c):
    """C := alpha*A*A^T + beta*C, only the lower triangle updated."""
    upd = alpha * (a @ a.T) + beta * c
    return jnp.tril(upd) + jnp.triu(c, 1)


def dtrsm_llnn(a, b):
    """Solve L X = B with L = tril(a), non-unit diag. B is m x n."""
    a, b = jnp.asarray(a), jnp.asarray(b)
    m = b.shape[0]
    low = jnp.tril(a)

    def body(i, x):
        mask = (jnp.arange(m) < i).astype(b.dtype)
        partial = (mask * low[i, :]) @ x
        return x.at[i, :].set((b[i, :] - partial) / low[i, i])

    return jax.lax.fori_loop(0, m, body, jnp.zeros_like(b))


# ------------------------------------------------------------ ABFT oracle

def abft_encode(a, b):
    """Encoded checksums for C = A @ B.

    Cr_enc = A @ (B e)   — predicted row sums of C    (length M)
    Cc_enc = (e^T A) @ B — predicted column sums of C (length N)
    """
    cr_enc = a @ jnp.sum(b, axis=1)
    cc_enc = jnp.sum(a, axis=0) @ b
    return cr_enc, cc_enc


def abft_reference(c):
    """Reference checksums computed from the actual C."""
    return jnp.sum(c, axis=1), jnp.sum(c, axis=0)


def gemm_with_checksums(a, b):
    c = a @ b
    cr_ref, cc_ref = abft_reference(c)
    cr_enc, cc_enc = abft_encode(a, b)
    return c, cr_ref, cc_ref, cr_enc, cc_enc
