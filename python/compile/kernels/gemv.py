"""Level-2 DGEMV Pallas kernels (paper §3.2.1) — plain and DMR-protected.

The paper unrolls the i-loop R_i=4 times so each x_j load is reused from a
register, and unrolls the j-loop 8 wide for AVX-512. The Pallas adaptation:
a (bm, bn) block of A is staged into VMEM together with a (bn,) block of x;
every x element is reused bm times from VMEM — the same register-reuse
argument at block granularity. No cache blocking of A (the paper
deliberately avoids it to keep A's accesses streaming): A's index map walks
row-panels left to right, exactly once.

Grid is (m/bm, n/bn); the y block accumulates across the j dimension and is
finalized with alpha/beta on the last j step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 64
DEFAULT_BN = 256


def _check(m, n, bm, bn):
    if m % bm != 0 or n % bn != 0:
        raise ValueError(f"shape ({m},{n}) not divisible by block ({bm},{bn})")


# ------------------------------------------------------------------ plain

def _dgemv_kernel(ab_ref, a_ref, x_ref, y_ref, o_ref):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ x_ref[...]

    @pl.when(j == nj - 1)
    def _():
        alpha = ab_ref[0]
        beta = ab_ref[1]
        o_ref[...] = alpha * o_ref[...] + beta * y_ref[...]


def dgemv(alpha, a, x, beta, y, *, bm=DEFAULT_BM, bn=DEFAULT_BN, interpret=True):
    """y := alpha * A @ x + beta * y for an (m, n) matrix A."""
    m, n = a.shape
    _check(m, n, bm, bn)
    ab = jnp.stack([alpha, beta]).reshape(2)
    return pl.pallas_call(
        _dgemv_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((2,), lambda i, j: (0,)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=interpret,
    )(ab, a, x, y)


# -------------------------------------------------------------------- DMR

def _dgemv_dmr_kernel(ab_ref, a_ref, x_ref, y_ref, inject_ref, o_ref, err_ref, *, bm):
    """Duplicate the per-block matvec partials (the compute instructions);
    loads are shared — the paper's sphere of replication. The injection
    operand is [flag, row, jblk, delta]: the primary partial of row `row`
    is perturbed by `delta` on j-step `jblk`."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    inject = inject_ref[...]
    flag, row, jblk, delta = inject[0], inject[1], inject[2], inject[3]

    p1 = a_ref[...] @ x_ref[...]
    rows = (i * bm + jnp.arange(bm)).astype(p1.dtype)
    hit = (flag > 0) & (jblk.astype(jnp.int32) == j) & (rows == row)
    p1 = p1 + jnp.where(hit, delta, jnp.zeros_like(p1))
    p2 = a_ref[...] @ x_ref[...]  # duplicated compute stream
    mismatch = p1 != p2
    p3 = a_ref[...] @ x_ref[...]  # recovery recomputation
    verified = jnp.where(mismatch & (p3 == p2), p3, p1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += verified

    @pl.when(j == nj - 1)
    def _():
        o_ref[...] = ab_ref[0] * o_ref[...] + ab_ref[1] * y_ref[...]

    @pl.when((i == 0) & (j == 0))
    def _():
        err_ref[...] = jnp.zeros_like(err_ref)

    err_ref[...] += jnp.sum(mismatch.astype(err_ref.dtype), keepdims=True)


def dgemv_dmr(alpha, a, x, beta, y, inject, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
              interpret=True):
    """Returns (y', errors_detected[1])."""
    m, n = a.shape
    _check(m, n, bm, bn)
    ab = jnp.stack([alpha, beta]).reshape(2)
    kern = lambda abr, ar, xr, yr, ir, o, e: _dgemv_dmr_kernel(
        abr, ar, xr, yr, ir, o, e, bm=bm
    )
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((2,), lambda i, j: (0,)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((4,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), a.dtype),
            jax.ShapeDtypeStruct((1,), a.dtype),
        ],
        interpret=interpret,
    )(ab, a, x, y, inject)
