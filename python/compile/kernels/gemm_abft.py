"""Fused online-ABFT DGEMM Pallas kernel (paper §5.2, Fig. 4 right side).

One rank-K_c update C' = C + A_panel @ B_panel computed together with all
four checksum vectors, reusing every A/B/C block already resident in VMEM —
the paper's kernel fusion that turns the O(n^2) checksum work from a
memory-bound extra pass into pure compute:

  dCr_enc[i] += A(i,k) @ (B(k,j) @ e)     fused where B's block is loaded
  dCc_enc[j] += (e^T @ A(i,k)) @ B(k,j)   fused where A's block is loaded
  Cr_ref[i]   = C'(i,:) @ e               fused where C's block is written
  Cc_ref[j]   = e^T @ C'(:,j)             fused where C's block is written

The Rust coordinator (ft/abft.rs) maintains the running encoded checksums
across rank-k steps (Cr_enc += dCr_enc), compares them to the reference
checksums after every step (the paper's per-rank-k verification interval),
locates (i_err, j_err) from the disagreeing row/column positions and
corrects C[i,j] -= delta online — no checkpoint/rollback, exactly the
paper's lightweight error model.

Fault injection: operand [flag, i, j, delta]; when armed, C'(i,j) is
perturbed *after* the accumulation and *before* the reference checksums
read C' — so the reference checksums see the corruption (they are computed
from the actual C) while the encoded checksums (derived from A and B) do
not, which is precisely what makes the error detectable.

NOTE on revisiting: the ref-checksum output blocks are revisited with
other blocks interleaved (cc_ref[j] is touched for every i). This is legal
in interpret mode (outputs are array-backed); on real TPU the kernel would
be split per the Mosaic revisiting rule — see DESIGN.md §Hardware-Adaptation.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import DEFAULT_BM, DEFAULT_BN, DEFAULT_BK, _check


def _abft_kernel(a_ref, b_ref, c_ref, inject_ref, o_ref, crr_ref, ccr_ref,
                 cre_ref, cce_ref, *, bm, bn):
    i = pl.program_id(0)
    j = pl.program_id(1)
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    a_blk = a_ref[...]
    b_blk = b_ref[...]

    # ---- C accumulation (the original GEMM macro kernel) ----
    @pl.when(kk == 0)
    def _():
        o_ref[...] = c_ref[...]

    o_ref[...] += a_blk @ b_blk

    # ---- encoded checksums, fused with the blocks already in VMEM ----
    # dCr_enc[i] += A(i,k) @ rowsum(B(k,j)) summed over j,k
    @pl.when((j == 0) & (kk == 0))
    def _():
        cre_ref[...] = jnp.zeros_like(cre_ref)

    cre_ref[...] += a_blk @ jnp.sum(b_blk, axis=1)

    # dCc_enc[j] += colsum(A(i,k)) @ B(k,j) summed over i,k
    @pl.when((i == 0) & (kk == 0))
    def _():
        cce_ref[...] = jnp.zeros_like(cce_ref)

    cce_ref[...] += jnp.sum(a_blk, axis=0) @ b_blk

    # ---- finalize C' block: inject, then reference checksums ----
    @pl.when(kk == nk - 1)
    def _():
        inject = inject_ref[...]
        flag, ei, ej, delta = inject[0], inject[1], inject[2], inject[3]
        rows = (i * bm + jnp.arange(bm)).astype(flag.dtype)
        cols = (j * bn + jnp.arange(bn)).astype(flag.dtype)
        hit = (flag > 0) & (rows[:, None] == ei) & (cols[None, :] == ej)
        o_ref[...] += jnp.where(hit, delta, 0.0).astype(o_ref.dtype)

        final = o_ref[...]

        @pl.when(j == 0)
        def _():
            crr_ref[...] = jnp.zeros_like(crr_ref)

        crr_ref[...] += jnp.sum(final, axis=1)

        @pl.when(i == 0)
        def _():
            ccr_ref[...] = jnp.zeros_like(ccr_ref)

        ccr_ref[...] += jnp.sum(final, axis=0)


def dgemm_abft(a, b, c, inject, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
               bk=DEFAULT_BK, interpret=True):
    """Fused-ABFT rank-k update.

    Computes C' = C + A @ B (A: (m,kc), B: (kc,n), C: (m,n)) and returns
    (C', Cr_ref, Cc_ref, dCr_enc, dCc_enc):

      Cr_ref  (m,)  row sums of C'           (from the computed C')
      Cc_ref  (n,)  column sums of C'        (from the computed C')
      dCr_enc (m,)  A @ (B @ e)              (this update's contribution)
      dCc_enc (n,)  (e^T @ A) @ B            (this update's contribution)

    With kc = K this is the full fused-ABFT GEMM (the offline variant).
    """
    m, kc = a.shape
    kc2, n = b.shape
    assert kc == kc2, (kc, kc2)
    _check(m, n, kc, bm, bn, bk)
    kern = lambda ar, br, cr, ir, o, crr, ccr, cre, cce: _abft_kernel(
        ar, br, cr, ir, o, crr, ccr, cre, cce, bm=bm, bn=bn
    )
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, kc // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((4,), lambda i, j, kk: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), a.dtype),
            jax.ShapeDtypeStruct((m,), a.dtype),
            jax.ShapeDtypeStruct((n,), a.dtype),
            jax.ShapeDtypeStruct((m,), a.dtype),
            jax.ShapeDtypeStruct((n,), a.dtype),
        ],
        interpret=interpret,
    )(a, b, c, inject)
