"""DMR-protected Level-1 kernels with in-kernel fault injection (paper §4).

The paper duplicates computing instructions (not loads/stores) inside the
assembly loop body, compares with `vpcmpeqd`+`kortestw`, and on mismatch
recomputes the corrupted iteration (a third computation) before storing.

Pallas adaptation (see DESIGN.md §1): both compute streams are expressed in
the same kernel body over the same VMEM-resident block, so the duplicated
stream reuses the single load — the sphere of replication is exactly
"computing instructions only". Fault injection is an operand
`inject = [flag, idx, delta]` (f64[3]): when flag > 0 the primary stream's
element at global index `idx` is perturbed by `delta` *after* the primary
compute and *before* verification — the model of a transient ALU flip.

Recovery: disagreeing lanes are recomputed (third stream) and re-verified
against the duplicate; the kernel additionally emits a (1,)-shaped count of
detected faulty lanes which the Rust coordinator accumulates into metrics.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .level1 import DEFAULT_BLOCK, _grid1d


def _gidx(block):
    return pl.program_id(0) * block + jnp.arange(block)


def _corrupt(vals, inject, block):
    """Add inject[2] to the lane whose global index == inject[1] if armed."""
    flag, idx, delta = inject[0], inject[1], inject[2]
    hit = (flag > 0) & (_gidx(block).astype(vals.dtype) == idx)
    return vals + jnp.where(hit, delta, jnp.zeros_like(vals))


def _err_init(o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)


# ------------------------------------------------------- elementwise DMR

def _dmr_elementwise(compute, inject_ref, err_ref, out_ref, block):
    """Shared duplicate/verify/recover skeleton for elementwise kernels."""
    primary = _corrupt(compute(), inject_ref[...], block)
    duplicate = compute()
    mismatch = primary != duplicate
    recomputed = compute()  # paper's recovery: recompute corrupted iteration
    # re-verify the recomputation against the duplicate (consensus check)
    consensus = recomputed == duplicate
    out_ref[...] = jnp.where(mismatch & consensus, recomputed, primary)
    _err_init(err_ref)
    err_ref[...] += jnp.sum(mismatch.astype(err_ref.dtype), keepdims=True)


def _dscal_dmr_kernel(alpha_ref, x_ref, inject_ref, o_ref, err_ref, *, block):
    _dmr_elementwise(
        lambda: alpha_ref[0] * x_ref[...], inject_ref, err_ref, o_ref, block
    )


def dscal_dmr(alpha, x, inject, *, block=DEFAULT_BLOCK, interpret=True):
    """Returns (alpha * x corrected, errors_detected[1])."""
    (n,) = x.shape
    kern = lambda a, xr, ir, o, e: _dscal_dmr_kernel(a, xr, ir, o, e, block=block)
    return pl.pallas_call(
        kern,
        grid=_grid1d(n, block),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=interpret,
    )(alpha.reshape(1), x, inject)


def _daxpy_dmr_kernel(alpha_ref, x_ref, y_ref, inject_ref, o_ref, err_ref, *, block):
    _dmr_elementwise(
        lambda: alpha_ref[0] * x_ref[...] + y_ref[...],
        inject_ref,
        err_ref,
        o_ref,
        block,
    )


def daxpy_dmr(alpha, x, y, inject, *, block=DEFAULT_BLOCK, interpret=True):
    (n,) = x.shape
    kern = lambda a, xr, yr, ir, o, e: _daxpy_dmr_kernel(a, xr, yr, ir, o, e, block=block)
    return pl.pallas_call(
        kern,
        grid=_grid1d(n, block),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=interpret,
    )(alpha.reshape(1), x, y, inject)


# --------------------------------------------------------- reduction DMR

def _reduction_dmr(partial, inject_ref, o_ref, err_ref):
    """Duplicate the per-block partial reduction; corrupt the primary's
    partial when this block owns the injected index."""
    inject = inject_ref[...]
    flag, idx, delta = inject[0], inject[1], inject[2]
    p1 = partial()
    block_owns = (flag > 0) & (pl.program_id(0) == idx.astype(jnp.int32))
    p1 = p1 + jnp.where(block_owns, delta, jnp.zeros_like(p1))
    p2 = partial()
    mismatch = p1 != p2
    p3 = partial()
    verified = jnp.where(mismatch & (p3 == p2), p3, p1)

    @pl.when(pl.program_id(0) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += verified
    _err_init(err_ref)
    err_ref[...] += mismatch.astype(err_ref.dtype)


def _ddot_dmr_kernel(x_ref, y_ref, inject_ref, o_ref, err_ref):
    _reduction_dmr(
        lambda: jnp.sum(x_ref[...] * y_ref[...], keepdims=True),
        inject_ref,
        o_ref,
        err_ref,
    )


def ddot_dmr(x, y, inject, *, block=DEFAULT_BLOCK, interpret=True):
    """Returns (dot[1], errors_detected[1]). inject idx is a *block* index."""
    (n,) = x.shape
    return pl.pallas_call(
        _ddot_dmr_kernel,
        grid=_grid1d(n, block),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=interpret,
    )(x, y, inject)


def _sumsq_dmr_kernel(x_ref, inject_ref, o_ref, err_ref):
    def partial():
        blk = x_ref[...]
        return jnp.sum(blk * blk, keepdims=True)

    _reduction_dmr(partial, inject_ref, o_ref, err_ref)


def dnrm2_dmr(x, inject, *, block=DEFAULT_BLOCK, interpret=True):
    """Returns (unscaled nrm2[1], errors_detected[1])."""
    (n,) = x.shape
    ssq, err = pl.pallas_call(
        _sumsq_dmr_kernel,
        grid=_grid1d(n, block),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=interpret,
    )(x, inject)
    return jnp.sqrt(ssq), err
