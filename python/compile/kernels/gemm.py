"""Level-3 DGEMM Pallas kernel (paper §3.3.2).

The paper's macro kernel updates an (M_C x N_C) block of C by iterating
micro kernels over packed A (M_R x K_C) and B (K_C x N_R) panels. The
Pallas adaptation: grid (i, j, k) with a (bm, bn) output tile accumulated
over the k dimension inside VMEM; the BlockSpec index maps *are* the
packing schedule (each A row-panel and B column-panel is staged into VMEM
exactly when the macro-kernel loop would touch it), and the MXU systolic
array plays the role of the AVX-512 FMA micro kernel.

Block sizes are the tuning parameters the paper calls M_C/N_C/K_C; the
runtime config (rust/src/config.rs) selects per-profile values.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 64
DEFAULT_BN = 64
DEFAULT_BK = 64


def _check(m, n, k, bm, bn, bk):
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"shape ({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})"
        )


def _dgemm_kernel(ab_ref, a_ref, b_ref, c_ref, o_ref):
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]

    @pl.when(kk == nk - 1)
    def _():
        o_ref[...] = ab_ref[0] * o_ref[...] + ab_ref[1] * c_ref[...]


def dgemm(alpha, a, b, beta, c, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
          interpret=True):
    """C := alpha * A @ B + beta * C. A is (m,k), B is (k,n), C is (m,n)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (k, k2)
    _check(m, n, k, bm, bn, bk)
    ab = jnp.stack([alpha, beta]).reshape(2)
    return pl.pallas_call(
        _dgemm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((2,), lambda i, j, kk: (0,)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(ab, a, b, c)
