"""Level-1 BLAS Pallas kernels (the paper's §3.1).

Memory-bound vector kernels. The AVX-512 adaptation: an AVX-512 register
holding 8 doubles becomes a Pallas block of BLOCK doubles staged through
VMEM; the BlockSpec index map is the explicit HBM->VMEM schedule the paper
expressed with `prefetcht0`. Reductions (ddot, dnrm2, dasum) accumulate a
(1,)-shaped output across a 1-D grid, the Pallas analog of the paper's
"horizontal reduction after the j-loop".

All kernels require the vector length to be a multiple of `block`; the L2
drivers in model.py pad and mask. interpret=True is mandatory on this image
(CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _grid1d(n, block):
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    return (n // block,)


# ----------------------------------------------------------------- dscal

def _dscal_kernel(alpha_ref, x_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...]


def dscal(alpha, x, *, block=DEFAULT_BLOCK, interpret=True):
    """x := alpha * x (returns the scaled vector)."""
    (n,) = x.shape
    return pl.pallas_call(
        _dscal_kernel,
        grid=_grid1d(n, block),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(alpha.reshape(1), x)


# ----------------------------------------------------------------- daxpy

def _daxpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


def daxpy(alpha, x, y, *, block=DEFAULT_BLOCK, interpret=True):
    """y := alpha * x + y."""
    (n,) = x.shape
    return pl.pallas_call(
        _daxpy_kernel,
        grid=_grid1d(n, block),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(alpha.reshape(1), x, y)


# ------------------------------------------------------------------ ddot

def _ddot_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...] * y_ref[...], keepdims=True)


def ddot(x, y, *, block=DEFAULT_BLOCK, interpret=True):
    """Returns (1,)-shaped dot(x, y)."""
    (n,) = x.shape
    return pl.pallas_call(
        _ddot_kernel,
        grid=_grid1d(n, block),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=interpret,
    )(x, y)


# ----------------------------------------------------------------- dnrm2

def _sumsq_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = x_ref[...]
    o_ref[...] += jnp.sum(blk * blk, keepdims=True)


def dnrm2(x, *, block=DEFAULT_BLOCK, interpret=True):
    """Returns (1,)-shaped unscaled 2-norm sqrt(sum(x^2)).

    Overflow scaling lives in the L2 driver (model.py), mirroring the
    paper's split between the hot AVX-512 kernel and the C driver.
    """
    (n,) = x.shape
    ssq = pl.pallas_call(
        _sumsq_kernel,
        grid=_grid1d(n, block),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=interpret,
    )(x)
    return jnp.sqrt(ssq)


# ----------------------------------------------------------------- dasum

def _dasum_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(jnp.abs(x_ref[...]), keepdims=True)


def dasum(x, *, block=DEFAULT_BLOCK, interpret=True):
    (n,) = x.shape
    return pl.pallas_call(
        _dasum_kernel,
        grid=_grid1d(n, block),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=interpret,
    )(x)


# ------------------------------------------------------------------ drot

def _drot_kernel(cs_ref, x_ref, y_ref, ox_ref, oy_ref):
    c = cs_ref[0]
    s = cs_ref[1]
    xb = x_ref[...]
    yb = y_ref[...]
    ox_ref[...] = c * xb + s * yb
    oy_ref[...] = c * yb - s * xb


def drot(x, y, c, s, *, block=DEFAULT_BLOCK, interpret=True):
    """Apply a Givens rotation to (x, y)."""
    (n,) = x.shape
    cs = jnp.stack([c, s]).reshape(2)
    return pl.pallas_call(
        _drot_kernel,
        grid=_grid1d(n, block),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=interpret,
    )(cs, x, y)


# ----------------------------------------------------------------- drotm

def _drotm_kernel(h_ref, x_ref, y_ref, ox_ref, oy_ref):
    # h_ref holds the *resolved* H entries [h11, h21, h12, h22] — the
    # flag dispatch happens once in the driver, outside the grid (the
    # paper hoists the flag branch out of the loop the same way).
    h11, h21, h12, h22 = h_ref[0], h_ref[1], h_ref[2], h_ref[3]
    xb = x_ref[...]
    yb = y_ref[...]
    ox_ref[...] = h11 * xb + h12 * yb
    oy_ref[...] = h21 * xb + h22 * yb


def drotm(x, y, param, *, block=DEFAULT_BLOCK, interpret=True):
    """Modified Givens rotation; param = [flag, h11, h21, h12, h22]."""
    (n,) = x.shape
    flag = param[0]
    h11 = jnp.where(flag == 0.0, 1.0, param[1])
    h22 = jnp.where(flag == 0.0, 1.0, param[4])
    h12 = jnp.where(flag == 1.0, 1.0, param[3])
    h21 = jnp.where(flag == 1.0, -1.0, param[2])
    # flag == -2 → identity H
    ident = flag == -2.0
    h11 = jnp.where(ident, 1.0, h11)
    h22 = jnp.where(ident, 1.0, h22)
    h12 = jnp.where(ident, 0.0, h12)
    h21 = jnp.where(ident, 0.0, h21)
    h = jnp.stack([h11, h21, h12, h22]).astype(x.dtype)
    return pl.pallas_call(
        _drotm_kernel,
        grid=_grid1d(n, block),
        in_specs=[
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=interpret,
    )(h, x, y)
