"""Layer-2 routine drivers: jax graphs composing the Pallas kernels.

This is the analog of the paper's C-level BLAS drivers sitting above the
assembly kernels: blocked DTRSV/DTRSM panel algorithms that cast the bulk
of their work onto the DGEMV/DGEMM kernels (paper §3.2.2, §3.3.3), the
symmetric/triangular packing preprocessing for DSYMM/DTRMM (§6.2.3), and
the FT drivers that thread injection operands through the kernels.

Everything here is lowered once by aot.py; nothing in this file runs on
the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import gemm as kgemm
from .kernels import gemm_abft as kabft
from .kernels import gemv as kgemv
from .kernels import level1 as k1
from .kernels import level1_dmr as k1d

# ------------------------------------------------------------- Level 1

def dscal(alpha, x, *, block=1024):
    return k1.dscal(alpha, x, block=block)


def daxpy(alpha, x, y, *, block=1024):
    return k1.daxpy(alpha, x, y, block=block)


def ddot(x, y, *, block=1024):
    return k1.ddot(x, y, block=block)


def dnrm2(x, *, block=1024):
    # Unscaled kernel (overflow scaling is not exercised by the benches;
    # the Rust native dnrm2 implements the scaled variant).
    return k1.dnrm2(x, block=block)


def dasum(x, *, block=1024):
    return k1.dasum(x, block=block)


def drot(x, y, c, s, *, block=1024):
    return k1.drot(x, y, c, s, block=block)


def dscal_dmr(alpha, x, inject, *, block=1024):
    return k1d.dscal_dmr(alpha, x, inject, block=block)


def daxpy_dmr(alpha, x, y, inject, *, block=1024):
    return k1d.daxpy_dmr(alpha, x, y, inject, block=block)


def ddot_dmr(x, y, inject, *, block=1024):
    return k1d.ddot_dmr(x, y, inject, block=block)


def dnrm2_dmr(x, inject, *, block=1024):
    return k1d.dnrm2_dmr(x, inject, block=block)


# ------------------------------------------------------------- Level 2

def dgemv(alpha, a, x, beta, y, *, bm=64, bn=256):
    return kgemv.dgemv(alpha, a, x, beta, y, bm=bm, bn=bn)


def dgemv_dmr(alpha, a, x, beta, y, inject, *, bm=64, bn=256):
    return kgemv.dgemv_dmr(alpha, a, x, beta, y, inject, bm=bm, bn=bn)


def _diag_solve_vec(diag, rhs):
    """Forward-substitute a (B,B) lower-triangular block against rhs (B,).

    The paper's Level-1 DDOT path for the diagonal section (Fig. 1 right).
    """
    B = rhs.shape[0]

    def body(r, xb):
        mask = (jnp.arange(B) < r).astype(diag.dtype)
        partial = jnp.dot(mask * diag[r, :], xb)
        return xb.at[r].set((xb[r] - partial) / diag[r, r])

    return jax.lax.fori_loop(0, B, body, rhs)


def dtrsv(a, b, *, panel=4, bn=64):
    """Solve tril(A) x = b, blocked: panel update via the DGEMV kernel,
    (panel x panel) diagonal block via forward substitution (paper §3.2.2).

    `panel` is the paper's block size B: 4 = FT-BLAS tuned choice (cast the
    maximum work onto DGEMV), 64 = the OpenBLAS default the paper beats.
    """
    n = b.shape[0]
    assert n % panel == 0, (n, panel)
    nsteps = n // panel
    zeros_p = jnp.zeros((panel,), b.dtype)
    one = jnp.asarray(1.0, b.dtype)
    zero = jnp.asarray(0.0, b.dtype)

    def body(t, x):
        row0 = t * panel
        row_panel = jax.lax.dynamic_slice(a, (row0, 0), (panel, n))
        xm = jnp.where(jnp.arange(n) < row0, x, 0.0)
        upd = kgemv.dgemv(one, row_panel, xm, zero, zeros_p, bm=panel, bn=bn)
        xb = jax.lax.dynamic_slice(x, (row0,), (panel,)) - upd
        diag = jax.lax.dynamic_slice(a, (row0, row0), (panel, panel))
        xb = _diag_solve_vec(diag, xb)
        return jax.lax.dynamic_update_slice(x, xb, (row0,))

    return jax.lax.fori_loop(0, nsteps, body, b)


def dtrsv_dmr(a, b, inject, *, panel=4, bn=64):
    """DMR-protected blocked DTRSV.

    The DGEMV panel updates run through the DMR gemv kernel; the diagonal
    forward substitution is duplicated and verified at the driver level
    (it is O(n*panel) work — the paper's Level-1 DDOT section).

    inject = [flag, step, row, delta]: arms the gemv DMR injection on panel
    step `step` (row index is panel-local).
    """
    n = b.shape[0]
    assert n % panel == 0
    nsteps = n // panel
    zeros_p = jnp.zeros((panel,), b.dtype)
    one = jnp.asarray(1.0, b.dtype)
    zero = jnp.asarray(0.0, b.dtype)

    def body(t, carry):
        x, errs = carry
        row0 = t * panel
        row_panel = jax.lax.dynamic_slice(a, (row0, 0), (panel, n))
        xm = jnp.where(jnp.arange(n) < row0, x, 0.0)
        armed = (inject[0] > 0) & (inject[1].astype(jnp.int32) == t)
        kinj = jnp.stack(
            [jnp.where(armed, 1.0, 0.0), inject[2], jnp.asarray(0.0, b.dtype), inject[3]]
        )
        upd, e = kgemv.dgemv_dmr(
            one, row_panel, xm, zero, zeros_p, kinj, bm=panel, bn=bn
        )
        xb = jax.lax.dynamic_slice(x, (row0,), (panel,)) - upd
        diag = jax.lax.dynamic_slice(a, (row0, row0), (panel, panel))
        s1 = _diag_solve_vec(diag, xb)
        s2 = _diag_solve_vec(diag, xb)  # duplicated diagonal solve (DMR)
        xb = jnp.where(s1 == s2, s1, _diag_solve_vec(diag, xb))
        return jax.lax.dynamic_update_slice(x, xb, (row0,)), errs + e[0]

    x, errs = jax.lax.fori_loop(0, nsteps, body, (b, jnp.asarray(0.0, b.dtype)))
    return x, errs.reshape(1)


# ------------------------------------------------------------- Level 3

def dgemm(alpha, a, b, beta, c, *, bm=64, bn=64, bk=64):
    return kgemm.dgemm(alpha, a, b, beta, c, bm=bm, bn=bn, bk=bk)


def dsymm(alpha, a, b, beta, c, *, bm=64, bn=64, bk=64):
    """C := alpha*sym(A)*B + beta*C, A referenced by its lower triangle.

    The symmetrization is the packing-routine modification the paper
    describes for DSYMM: the packed buffer reads A(i,j) from the lower
    triangle regardless of which half the macro kernel asks for.
    """
    full = jnp.tril(a) + jnp.tril(a, -1).T
    return kgemm.dgemm(alpha, full, b, beta, c, bm=bm, bn=bn, bk=bk)


def dtrmm(alpha, a, b, *, bm=64, bn=64, bk=64):
    """B := alpha * tril(A) @ B — triangular packing + the GEMM kernel."""
    low = jnp.tril(a)
    beta = jnp.asarray(0.0, b.dtype)
    return kgemm.dgemm(alpha, low, b, beta, jnp.zeros_like(b), bm=bm, bn=bn, bk=bk)


def dsyrk(alpha, a, beta, c, *, bm=64, bn=64, bk=64):
    """C := alpha*A*A^T + beta*C (lower triangle updated)."""
    upd = kgemm.dgemm(alpha, a, a.T, beta, c, bm=bm, bn=bn, bk=bk)
    return jnp.tril(upd) + jnp.triu(c, 1)


def _diag_solve_mat(diag, rhs):
    """Forward-substitute (B,B) lower-tri block against rhs (B, ncols)."""
    B = rhs.shape[0]

    def body(r, xb):
        mask = (jnp.arange(B) < r).astype(diag.dtype)
        partial = (mask * diag[r, :]) @ xb
        return xb.at[r, :].set((xb[r, :] - partial) / diag[r, r])

    return jax.lax.fori_loop(0, B, body, rhs)


def dtrsm(a, b, *, panel=16, bn=64, bk=64):
    """Solve tril(A) X = B (left, lower, non-unit), blocked (paper §3.3.3):
    off-diagonal panels go through the DGEMM kernel (the paper's
    macro_kernel_gemm call), the (panel x panel) diagonal block through
    forward substitution (the paper's macro_kernel_trsm)."""
    m, n = b.shape
    assert m % panel == 0
    nsteps = m // panel
    one = jnp.asarray(1.0, b.dtype)
    zero = jnp.asarray(0.0, b.dtype)
    zblock = jnp.zeros((panel, n), b.dtype)

    def body(t, x):
        row0 = t * panel
        row_panel = jax.lax.dynamic_slice(a, (row0, 0), (panel, m))
        xm = jnp.where((jnp.arange(m) < row0)[:, None], x, 0.0)
        upd = kgemm.dgemm(one, row_panel, xm, zero, zblock,
                          bm=panel, bn=bn, bk=bk)
        xb = jax.lax.dynamic_slice(x, (row0, 0), (panel, n)) - upd
        diag = jax.lax.dynamic_slice(a, (row0, row0), (panel, panel))
        xb = _diag_solve_mat(diag, xb)
        return jax.lax.dynamic_update_slice(x, xb, (row0, 0))

    return jax.lax.fori_loop(0, nsteps, body, b)


# --------------------------------------------------------------- ABFT FT

def dgemm_abft(a, b, c, inject, *, bm=64, bn=64, bk=64):
    """Fused-ABFT rank-k update (see kernels/gemm_abft.py)."""
    return kabft.dgemm_abft(a, b, c, inject, bm=bm, bn=bn, bk=bk)


def dgemm_abft_full(a, b, inject, *, bm=64, bn=64, bk=64):
    """Full fused-ABFT GEMM, C = A @ B from zero (offline verification)."""
    m = a.shape[0]
    n = b.shape[1]
    c0 = jnp.zeros((m, n), a.dtype)
    return kabft.dgemm_abft(a, b, c0, inject, bm=bm, bn=bn, bk=bk)


def dsymm_abft(a, b, c, inject, *, bm=64, bn=64, bk=64):
    full = jnp.tril(a) + jnp.tril(a, -1).T
    return kabft.dgemm_abft(full, b, c, inject, bm=bm, bn=bn, bk=bk)


def dtrmm_abft(a, b, inject, *, bm=64, bn=64, bk=64):
    low = jnp.tril(a)
    m, n = b.shape
    c0 = jnp.zeros((m, n), a.dtype)
    return kabft.dgemm_abft(low, b, c0, inject, bm=bm, bn=bn, bk=bk)


def dtrsm_ft(a, b, inject, *, panel=16, bn=64, bk=64):
    """FT DTRSM (paper's scheme): each off-diagonal GEMM panel update runs
    through the fused-ABFT kernel and is verified+corrected in-driver per
    step (online); the diagonal solve is DMR-duplicated and verified.

    inject = [flag, step, i, j, delta]: corrupts the GEMM update of panel
    step `step` at local position (i, j).

    Returns (X, errors_detected[1]).
    """
    m, n = b.shape
    assert m % panel == 0
    nsteps = m // panel
    zblock = jnp.zeros((panel, n), b.dtype)
    eps = jnp.asarray(jnp.finfo(b.dtype).eps, b.dtype)

    def body(t, carry):
        x, errs = carry
        row0 = t * panel
        row_panel = jax.lax.dynamic_slice(a, (row0, 0), (panel, m))
        xm = jnp.where((jnp.arange(m) < row0)[:, None], x, 0.0)
        armed = (inject[0] > 0) & (inject[1].astype(jnp.int32) == t)
        kinj = jnp.stack(
            [jnp.where(armed, 1.0, 0.0), inject[2], inject[3], inject[4]]
        )
        upd, crr, ccr, cre, cce = kabft.dgemm_abft(
            row_panel, xm, zblock, kinj, bm=panel, bn=bn, bk=bk
        )
        # Online verify + locate + correct (paper §5: one error per
        # verification interval, no rollback).
        scale = jnp.max(jnp.abs(cre)) + jnp.max(jnp.abs(crr)) + 1.0
        tol = scale * eps * m * 64.0
        dr = crr - cre
        dc = ccr - cce
        bad = jnp.max(jnp.abs(dr)) > tol
        ei = jnp.argmax(jnp.abs(dr))
        ej = jnp.argmax(jnp.abs(dc))
        delta = dr[ei]
        corr = jnp.where(bad, delta, 0.0)
        upd = upd.at[ei, ej].add(-corr)
        errs = errs + jnp.where(bad, 1.0, 0.0)

        xb = jax.lax.dynamic_slice(x, (row0, 0), (panel, n)) - upd
        diag = jax.lax.dynamic_slice(a, (row0, row0), (panel, panel))
        s1 = _diag_solve_mat(diag, xb)
        s2 = _diag_solve_mat(diag, xb)  # DMR-duplicated diagonal solve
        xb = jnp.where(s1 == s2, s1, _diag_solve_mat(diag, xb))
        return jax.lax.dynamic_update_slice(x, xb, (row0, 0)), errs

    x, errs = jax.lax.fori_loop(
        0, nsteps, body, (b, jnp.asarray(0.0, b.dtype))
    )
    return x, errs.reshape(1)
