"""FT-BLAS compile path (build-time only; never imported at runtime).

Layer 1: kernels/ (Pallas), Layer 2: model.py (jax routine drivers),
AOT bridge: aot.py (HLO text -> artifacts/ consumed by the Rust runtime).
"""

import jax

jax.config.update("jax_enable_x64", True)
