"""AOT bridge: lower every artifact spec to HLO *text* + a manifest.

HLO text (NOT `lowered.compiler_ir("hlo")`-proto serialization) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the Rust `xla` crate binds)
rejects; the text parser reassigns ids and round-trips cleanly.

Run once at build time (`make artifacts`); the Rust runtime then consumes
artifacts/<profile>/manifest.tsv + *.hlo.txt with no Python anywhere near
the request path.

Usage:
  python -m compile.aot --out-dir ../artifacts [--profile skylake_sim]
                        [--filter dgemm,dtrsv] [--list] [--dump-stats]
"""

import argparse
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import specs as specs_mod  # noqa: E402

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(shape):
    if len(shape) == 0:
        return "scalar"
    return "x".join(str(d) for d in shape)


def lower_spec(spec):
    args = spec.example_args()
    lowered = jax.jit(spec.fn).lower(*args)
    text = to_hlo_text(lowered)
    out_shapes = [tuple(o.shape) for o in jax.eval_shape(spec.fn, *args)]
    return text, out_shapes


def manifest_line(spec, fname, out_shapes):
    ins = " ".join(f"f64:{_shape_str(s)}" for s in spec.inputs)
    outs = " ".join(f"f64:{_shape_str(s)}" for s in out_shapes)
    meta = " ".join(f"{k}={v}" for k, v in sorted(spec.meta.items()))
    return "\t".join(
        [spec.name, fname, spec.routine, spec.variant, ins, outs, meta]
    )


def hlo_op_counts(text: str) -> dict:
    """Histogram of HLO opcodes in a module's text (entry + fusions)."""
    import re

    counts = {}
    for line in text.splitlines():
        m = re.match(r"\s*(%?[\w.-]+)\s*=\s*\S+\s+(\w+)\(", line)
        if m:
            op = m.group(2)
            counts[op] = counts.get(op, 0) + 1
    return counts


def dump_stats(all_specs) -> None:
    """The L2 profiling pass: per-artifact HLO op counts, so redundant
    recomputation or fusion barriers introduced by the checksum ops show
    up as op-count inflation vs the unprotected variant."""
    interesting = ["dot", "multiply", "add", "reduce", "fusion", "copy",
                   "transpose", "broadcast", "while"]
    print(f"{'artifact':<34} {'total':>6} " +
          " ".join(f"{op:>9}" for op in interesting))
    for spec in all_specs:
        text, _ = lower_spec(spec)
        counts = hlo_op_counts(text)
        total = sum(counts.values())
        print(f"{spec.name:<34} {total:>6} " +
              " ".join(f"{counts.get(op, 0):>9}" for op in interesting))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", default="skylake_sim",
                    choices=["skylake_sim", "cascade_sim"])
    ap.add_argument("--filter", default="",
                    help="comma-separated routine names to lower (default all)")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--dump-stats", action="store_true",
                    help="print HLO op-count stats per artifact (the L2 "
                         "no-redundant-recomputation check) instead of "
                         "writing artifacts")
    args = ap.parse_args()

    all_specs = specs_mod.build_specs(args.profile)
    if args.filter:
        keep = set(args.filter.split(","))
        all_specs = [s for s in all_specs if s.routine in keep]
    if args.list:
        for s in all_specs:
            print(s.name)
        return
    if args.dump_stats:
        dump_stats(all_specs)
        return

    out_dir = args.out_dir
    if args.profile != "skylake_sim":
        out_dir = os.path.join(out_dir, args.profile)
    os.makedirs(out_dir, exist_ok=True)

    lines = [f"# ftblas manifest v{MANIFEST_VERSION} profile={args.profile}"]
    t0 = time.time()
    for i, spec in enumerate(all_specs):
        t1 = time.time()
        text, out_shapes = lower_spec(spec)
        fname = f"{spec.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        lines.append(manifest_line(spec, fname, out_shapes))
        print(f"[{i + 1}/{len(all_specs)}] {spec.name}: "
              f"{len(text)} chars in {time.time() - t1:.1f}s", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"lowered {len(all_specs)} artifacts to {out_dir} "
          f"in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
