"""Fused-ABFT GEMM kernel: checksum invariants, injection detection,
location, and online correction across rank-k steps (paper §5)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

from conftest import assert_close

NOINJ = jnp.zeros(4)


def test_checksum_relationship_clean(rng):
    """Huang-Abraham invariant: encoded == reference when fault-free."""
    a = rng.standard_normal((128, 128))
    b = rng.standard_normal((128, 128))
    c, crr, ccr, cre, cce = model.dgemm_abft_full(
        jnp.asarray(a), jnp.asarray(b), NOINJ, bm=32, bn=32, bk=32)
    assert_close(c, a @ b, rtol=1e-9)
    assert_close(crr, cre, rtol=1e-8, atol=1e-8)
    assert_close(ccr, cce, rtol=1e-8, atol=1e-8)


def test_checksums_match_oracle(rng):
    a = rng.standard_normal((64, 96))
    b = rng.standard_normal((96, 128))
    c, crr, ccr, cre, cce = model.dgemm_abft_full(
        jnp.asarray(a), jnp.asarray(b), NOINJ, bm=32, bn=32, bk=32)
    ec, ecrr, eccr, ecre, ecce = ref.gemm_with_checksums(
        jnp.asarray(a), jnp.asarray(b))
    assert_close(c, ec, rtol=1e-9)
    assert_close(crr, ecrr, rtol=1e-9)
    assert_close(ccr, eccr, rtol=1e-9)
    assert_close(cre, ecre, rtol=1e-9)
    assert_close(cce, ecce, rtol=1e-9)


@settings(deadline=None, max_examples=8)
@given(
    ei=st.integers(min_value=0, max_value=127),
    ej=st.integers(min_value=0, max_value=127),
    delta=st.floats(min_value=1e-2, max_value=1e9,
                    allow_nan=False, allow_infinity=False),
)
def test_injection_detected_located_corrected(ei, ej, delta):
    """Property: a single injected error at (ei, ej) with magnitude delta
    (i) perturbs exactly C[ei, ej], (ii) shows up in the row/col checksum
    difference at exactly (ei, ej) with magnitude delta, and (iii) the
    decoded correction recovers the clean product."""
    rng = np.random.default_rng(ei * 131 + ej)
    a = rng.standard_normal((128, 128))
    b = rng.standard_normal((128, 128))
    inject = jnp.asarray([1.0, float(ei), float(ej), delta])
    c, crr, ccr, cre, cce = model.dgemm_abft_full(
        jnp.asarray(a), jnp.asarray(b), inject, bm=32, bn=32, bk=32)
    c = np.array(c)  # writable copy
    clean = a @ b

    dr = np.asarray(crr - cre)
    dc = np.asarray(ccr - cce)
    tol = 1e-6 * max(1.0, np.abs(clean).max())
    # detection + location
    assert np.abs(dr[ei]) > tol or delta < tol
    i_loc = int(np.argmax(np.abs(dr)))
    j_loc = int(np.argmax(np.abs(dc)))
    assert (i_loc, j_loc) == (ei, ej)
    # magnitude decode + correction: precision of the decoded magnitude is
    # limited by eps * delta * n (checksum summation error)
    c[i_loc, j_loc] -= dr[i_loc]
    atol = 1e-7 + abs(delta) * 128 * 2.3e-16 * 8
    np.testing.assert_allclose(c, clean, rtol=1e-7, atol=atol)


def test_online_rankk_chain(rng):
    """The paper's online scheme: C accumulated over K/Kc rank-k updates,
    encoded checksums carried by the caller, verified each step."""
    n, kc = 128, 32
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    c = jnp.zeros((n, n))
    cr_run = np.zeros(n)
    cc_run = np.zeros(n)
    for s in range(n // kc):
        ap = jnp.asarray(a[:, s * kc:(s + 1) * kc])
        bp = jnp.asarray(b[s * kc:(s + 1) * kc, :])
        c, crr, ccr, dcre, dcce = model.dgemm_abft(
            ap, bp, c, NOINJ, bm=32, bn=32, bk=32)
        cr_run += np.asarray(dcre)
        cc_run += np.asarray(dcce)
        # per-step verification interval: running encoded == reference
        np.testing.assert_allclose(cr_run, np.asarray(crr), rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(cc_run, np.asarray(ccr), rtol=1e-8, atol=1e-8)
    assert_close(c, a @ b, rtol=1e-9)


def test_online_rankk_chain_with_midstream_error(rng):
    """Inject in the middle step; correct online; later steps unaffected."""
    n, kc = 128, 32
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    c = jnp.zeros((n, n))
    cr_run = np.zeros(n)
    cc_run = np.zeros(n)
    ei, ej, delta = 77, 13, 1e4
    nsteps = n // kc
    for s in range(nsteps):
        inject = jnp.asarray([1.0, float(ei), float(ej), delta]) \
            if s == 1 else NOINJ
        ap = jnp.asarray(a[:, s * kc:(s + 1) * kc])
        bp = jnp.asarray(b[s * kc:(s + 1) * kc, :])
        c, crr, ccr, dcre, dcce = model.dgemm_abft(
            ap, bp, c, inject, bm=32, bn=32, bk=32)
        cr_run += np.asarray(dcre)
        cc_run += np.asarray(dcce)
        dr = np.asarray(crr) - cr_run
        dc = np.asarray(ccr) - cc_run
        tol = 1e-6 * max(1.0, float(np.abs(np.asarray(c)).max()))
        if np.abs(dr).max() > tol:
            i_loc = int(np.argmax(np.abs(dr)))
            j_loc = int(np.argmax(np.abs(dc)))
            assert (i_loc, j_loc) == (ei, ej)
            assert s == 1
            c = c.at[i_loc, j_loc].add(-dr[i_loc])
    assert_close(c, a @ b, rtol=1e-8)


def test_symm_abft_checksums(rng):
    n = 128
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    c0 = jnp.zeros((n, n))
    c, crr, ccr, cre, cce = model.dsymm_abft(
        jnp.asarray(a), jnp.asarray(b), c0, NOINJ, bm=32, bn=32, bk=32)
    full = np.tril(a) + np.tril(a, -1).T
    assert_close(c, full @ b, rtol=1e-9)
    assert_close(crr, cre, rtol=1e-8, atol=1e-8)


def test_trmm_abft_checksums(rng):
    n = 128
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    c, crr, ccr, cre, cce = model.dtrmm_abft(
        jnp.asarray(a), jnp.asarray(b), NOINJ, bm=32, bn=32, bk=32)
    assert_close(c, np.tril(a) @ b, rtol=1e-9)
    assert_close(ccr, cce, rtol=1e-8, atol=1e-8)


@settings(deadline=None, max_examples=8)
@given(
    step=st.integers(min_value=0, max_value=7),
    i=st.integers(min_value=0, max_value=15),
    j=st.integers(min_value=0, max_value=63),
    delta=st.floats(min_value=1.0, max_value=1e8,
                    allow_nan=False, allow_infinity=False),
)
def test_dtrsm_ft_corrects_any_panel_fault(step, i, j, delta):
    """FT DTRSM: fault in any panel's GEMM update is corrected online
    before it propagates through the solve."""
    rng = np.random.default_rng(step * 7 + i)
    m = 128
    a = np.tril(rng.standard_normal((m, m))) + 4 * np.eye(m)
    b = rng.standard_normal((m, m))
    inject = jnp.asarray([1.0, float(step), float(i), float(j), delta])
    x, errs = model.dtrsm_ft(jnp.asarray(a), jnp.asarray(b), inject,
                             panel=16, bn=32, bk=32)
    # step 0 has no off-diagonal panel work (xm is all zeros, still runs)
    assert_close(x, ref.dtrsm_llnn(a, b), rtol=5e-7, atol=5e-7)
