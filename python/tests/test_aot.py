"""AOT pipeline: spec registry consistency and HLO-text lowering."""

import jax
import pytest

from compile import aot, specs


def test_spec_names_unique():
    s = specs.build_specs("skylake_sim")
    names = [x.name for x in s]
    assert len(names) == len(set(names))


def test_spec_registry_covers_paper_routines():
    s = specs.build_specs("skylake_sim")
    routines = {x.routine for x in s}
    # the eight routines of paper Fig. 9 + the rest we ship
    for r in ("dscal", "dnrm2", "dgemv", "dtrsv",
              "dgemm", "dsymm", "dtrmm", "dtrsm"):
        assert r in routines, r


def test_every_dmr_or_ft_spec_has_inject_input():
    for s in specs.build_specs("skylake_sim"):
        if s.variant in ("dmr", "ft", "abft", "abft_rankk"):
            # last input is the injection operand (rank-1, len 3..5)
            assert len(s.inputs[-1]) == 1 and 3 <= s.inputs[-1][0] <= 5, s.name


def test_cascade_profile_differs():
    sky = {s.name: s.meta for s in specs.build_specs("skylake_sim")}
    cas = {s.name: s.meta for s in specs.build_specs("cascade_sim")}
    assert sky.keys() == cas.keys()
    diffs = [n for n in sky if sky[n] != cas[n]]
    assert diffs, "cascade_sim must use different tuning parameters"


@pytest.mark.parametrize("name", ["dscal_ori_n65536", "dgemm_ori_n128",
                                  "dgemm_abft_n128"])
def test_lowering_produces_hlo_text(name):
    s = [x for x in specs.build_specs("skylake_sim") if x.name == name][0]
    text, out_shapes = aot.lower_spec(s)
    assert "HloModule" in text
    assert len(out_shapes) >= 1
    line = aot.manifest_line(s, f"{s.name}.hlo.txt", out_shapes)
    fields = line.split("\t")
    assert len(fields) == 7
    assert fields[0] == name


def test_manifest_shape_grammar():
    s = [x for x in specs.build_specs("skylake_sim")
         if x.name == "dgemv_dmr_n256"][0]
    out_shapes = [tuple(o.shape) for o in jax.eval_shape(
        s.fn, *s.example_args())]
    line = aot.manifest_line(s, "f", out_shapes)
    ins = line.split("\t")[4].split(" ")
    assert ins[0] == "f64:scalar"
    assert ins[1] == "f64:256x256"
    assert ins[-1] == "f64:4"
