import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0xF7B1A5)


def assert_close(a, b, rtol=1e-10, atol=1e-10):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)
