"""Level-3 kernels/drivers vs oracles (paper §3.3): DGEMM, DTRSM, DSYMM,
DTRMM, DSYRK."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

from conftest import assert_close


@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (64, 64, 64, 32, 32, 32),
    (128, 128, 128, 64, 64, 64),
    (128, 64, 192, 32, 64, 64),
    (64, 192, 128, 64, 64, 32),
])
def test_dgemm_rect(rng, m, n, k, bm, bn, bk):
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n))
    c = rng.standard_normal((m, n))
    alpha, beta = jnp.asarray(1.25), jnp.asarray(-0.75)
    out = model.dgemm(alpha, jnp.asarray(a), jnp.asarray(b), beta,
                      jnp.asarray(c), bm=bm, bn=bn, bk=bk)
    assert_close(out, ref.dgemm(alpha, a, b, beta, c), rtol=1e-9)


def test_dgemm_beta_zero(rng):
    a = rng.standard_normal((64, 64))
    b = rng.standard_normal((64, 64))
    c = np.full((64, 64), np.nan)  # beta=0 must not propagate NaNs from C
    out = model.dgemm(jnp.asarray(1.0), jnp.asarray(a), jnp.asarray(b),
                      jnp.asarray(0.0), jnp.asarray(np.zeros((64, 64))),
                      bm=32, bn=32, bk=32)
    assert_close(out, a @ b, rtol=1e-9)


@settings(deadline=None, max_examples=8)
@given(
    mi=st.integers(min_value=1, max_value=3),
    ni=st.integers(min_value=1, max_value=3),
    ki=st.integers(min_value=1, max_value=3),
)
def test_dgemm_block_sweep(mi, ni, ki):
    """Block-shape sweep: result must not depend on the tiling."""
    m, n, k = 32 * mi, 32 * ni, 32 * ki
    rng = np.random.default_rng(m + n + k)
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n))
    c = rng.standard_normal((m, n))
    out = model.dgemm(jnp.asarray(1.0), jnp.asarray(a), jnp.asarray(b),
                      jnp.asarray(1.0), jnp.asarray(c), bm=32, bn=32, bk=32)
    assert_close(out, a @ b + c, rtol=1e-9)


def test_dsymm(rng):
    n = 128
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    c = rng.standard_normal((n, n))
    alpha, beta = jnp.asarray(0.5), jnp.asarray(2.0)
    out = model.dsymm(alpha, jnp.asarray(a), jnp.asarray(b), beta,
                      jnp.asarray(c), bm=32, bn=32, bk=32)
    assert_close(out, ref.dsymm_lower(alpha, a, b, beta, c), rtol=1e-9)


def test_dtrmm(rng):
    n = 128
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    out = model.dtrmm(jnp.asarray(1.5), jnp.asarray(a), jnp.asarray(b),
                      bm=32, bn=32, bk=32)
    assert_close(out, ref.dtrmm_lower(jnp.asarray(1.5), a, b), rtol=1e-9)


def test_dsyrk(rng):
    n = 128
    a = rng.standard_normal((n, n))
    c = rng.standard_normal((n, n))
    alpha, beta = jnp.asarray(1.0), jnp.asarray(0.5)
    out = model.dsyrk(alpha, jnp.asarray(a), beta, jnp.asarray(c),
                      bm=32, bn=32, bk=32)
    assert_close(out, ref.dsyrk_lower(alpha, a, beta, c), rtol=1e-9)


def _lower_tri(rng, n, dom=4.0):
    return np.tril(rng.standard_normal((n, n))) + dom * np.eye(n)


@pytest.mark.parametrize("m,n,panel", [(64, 64, 16), (128, 128, 16),
                                       (128, 64, 32), (256, 128, 16)])
def test_dtrsm(rng, m, n, panel):
    a = _lower_tri(rng, m)
    b = rng.standard_normal((m, n))
    out = model.dtrsm(jnp.asarray(a), jnp.asarray(b), panel=panel,
                      bn=32, bk=32)
    assert_close(out, ref.dtrsm_llnn(a, b), rtol=1e-8)


def test_dtrsm_residual(rng):
    m, n = 128, 128
    a = _lower_tri(rng, m)
    b = rng.standard_normal((m, n))
    x = np.asarray(model.dtrsm(jnp.asarray(a), jnp.asarray(b), panel=16,
                               bn=32, bk=32))
    resid = np.linalg.norm(np.tril(a) @ x - b) / np.linalg.norm(b)
    assert resid < 1e-10


def test_dtrsm_panel_invariance(rng):
    a = _lower_tri(rng, 128)
    b = rng.standard_normal((128, 128))
    x16 = model.dtrsm(jnp.asarray(a), jnp.asarray(b), panel=16, bn=32, bk=32)
    x32 = model.dtrsm(jnp.asarray(a), jnp.asarray(b), panel=32, bn=32, bk=32)
    assert_close(x16, x32, rtol=1e-9)
