"""Level-2 kernels/drivers vs oracles (paper §3.2): DGEMV, DTRSV."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gemv as kgemv
from compile.kernels import ref

from conftest import assert_close

NOINJ4 = jnp.zeros(4)


@pytest.mark.parametrize("m,n,bm,bn", [
    (128, 128, 32, 64),
    (256, 256, 64, 64),
    (128, 512, 64, 128),
    (512, 128, 64, 128),
])
def test_dgemv(rng, m, n, bm, bn):
    a = rng.standard_normal((m, n))
    x = rng.standard_normal(n)
    y = rng.standard_normal(m)
    alpha, beta = jnp.asarray(1.5), jnp.asarray(-0.25)
    out = kgemv.dgemv(alpha, jnp.asarray(a), jnp.asarray(x), beta,
                      jnp.asarray(y), bm=bm, bn=bn)
    assert_close(out, ref.dgemv(alpha, a, x, beta, y), rtol=1e-9)


def test_dgemv_alpha_beta_zero(rng):
    a = rng.standard_normal((128, 128))
    x = rng.standard_normal(128)
    y = rng.standard_normal(128)
    out = kgemv.dgemv(jnp.asarray(0.0), jnp.asarray(a), jnp.asarray(x),
                      jnp.asarray(1.0), jnp.asarray(y), bm=32, bn=64)
    assert_close(out, y)


def test_dgemv_dmr_clean(rng):
    a = rng.standard_normal((128, 128))
    x = rng.standard_normal(128)
    y = rng.standard_normal(128)
    alpha, beta = jnp.asarray(2.0), jnp.asarray(1.0)
    out, err = kgemv.dgemv_dmr(alpha, jnp.asarray(a), jnp.asarray(x), beta,
                               jnp.asarray(y), NOINJ4, bm=32, bn=64)
    assert float(err[0]) == 0.0
    assert_close(out, ref.dgemv(alpha, a, x, beta, y), rtol=1e-9)


@settings(deadline=None, max_examples=12)
@given(
    row=st.integers(min_value=0, max_value=127),
    jblk=st.integers(min_value=0, max_value=1),
    delta=st.floats(min_value=1e-4, max_value=1e10,
                    allow_nan=False, allow_infinity=False),
)
def test_dgemv_dmr_detects_and_corrects(row, jblk, delta):
    """Any single fault in a gemv partial is detected and corrected."""
    rng = np.random.default_rng(row * 7 + jblk)
    a = rng.standard_normal((128, 128))
    x = rng.standard_normal(128)
    y = rng.standard_normal(128)
    alpha, beta = jnp.asarray(1.0), jnp.asarray(0.5)
    inject = jnp.asarray([1.0, float(row), float(jblk), delta])
    out, err = kgemv.dgemv_dmr(alpha, jnp.asarray(a), jnp.asarray(x), beta,
                               jnp.asarray(y), inject, bm=32, bn=64)
    assert float(err[0]) == 1.0
    assert_close(out, ref.dgemv(alpha, a, x, beta, y), rtol=1e-9)


def _lower_tri(rng, n, dom=4.0):
    return np.tril(rng.standard_normal((n, n))) + dom * np.eye(n)


@pytest.mark.parametrize("n,panel", [(64, 4), (256, 4), (256, 64), (128, 8)])
def test_dtrsv(rng, n, panel):
    a = _lower_tri(rng, n)
    b = rng.standard_normal(n)
    out = model.dtrsv(jnp.asarray(a), jnp.asarray(b), panel=panel, bn=64)
    assert_close(out, ref.dtrsv_lower(a, b), rtol=1e-8)


def test_dtrsv_panel4_matches_panel64(rng):
    """The paper's tuning claim: block size changes performance, never
    results (both solve the same system)."""
    a = _lower_tri(rng, 256)
    b = rng.standard_normal(256)
    x4 = model.dtrsv(jnp.asarray(a), jnp.asarray(b), panel=4, bn=64)
    x64 = model.dtrsv(jnp.asarray(a), jnp.asarray(b), panel=64, bn=64)
    assert_close(x4, x64, rtol=1e-9)


def test_dtrsv_residual(rng):
    a = _lower_tri(rng, 256)
    b = rng.standard_normal(256)
    x = np.asarray(model.dtrsv(jnp.asarray(a), jnp.asarray(b), panel=4, bn=64))
    resid = np.linalg.norm(np.tril(a) @ x - b) / np.linalg.norm(b)
    assert resid < 1e-10


def test_dtrsv_dmr_clean(rng):
    a = _lower_tri(rng, 128)
    b = rng.standard_normal(128)
    out, err = model.dtrsv_dmr(jnp.asarray(a), jnp.asarray(b), NOINJ4,
                               panel=4, bn=64)
    assert float(err[0]) == 0.0
    assert_close(out, ref.dtrsv_lower(a, b), rtol=1e-8)


@settings(deadline=None, max_examples=10)
@given(
    step=st.integers(min_value=1, max_value=31),
    row=st.integers(min_value=0, max_value=3),
    delta=st.floats(min_value=1e-3, max_value=1e6,
                    allow_nan=False, allow_infinity=False),
)
def test_dtrsv_dmr_detects_and_corrects(step, row, delta):
    """A fault injected into any panel's gemv update must be corrected
    before it propagates into later panels (online correction)."""
    rng = np.random.default_rng(step)
    a = _lower_tri(rng, 128)
    b = rng.standard_normal(128)
    inject = jnp.asarray([1.0, float(step), float(row), delta])
    out, err = model.dtrsv_dmr(jnp.asarray(a), jnp.asarray(b), inject,
                               panel=4, bn=64)
    assert float(err[0]) == 1.0
    assert_close(out, ref.dtrsv_lower(a, b), rtol=1e-8)
