"""Level-1 Pallas kernels vs pure-jnp oracles (paper §3.1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import level1 as k1
from compile.kernels import level1_dmr as k1d
from compile.kernels import ref

from conftest import assert_close


def _vec(rng, n):
    return rng.standard_normal(n)


NOINJ = jnp.zeros(3)


@pytest.mark.parametrize("n,block", [(256, 64), (1024, 128), (4096, 1024)])
class TestPlainKernels:
    def test_dscal(self, rng, n, block):
        x = _vec(rng, n)
        alpha = jnp.asarray(2.75)
        assert_close(k1.dscal(alpha, jnp.asarray(x), block=block),
                     ref.dscal(alpha, x))

    def test_daxpy(self, rng, n, block):
        x, y = _vec(rng, n), _vec(rng, n)
        alpha = jnp.asarray(-0.5)
        assert_close(k1.daxpy(alpha, jnp.asarray(x), jnp.asarray(y), block=block),
                     ref.daxpy(alpha, x, y))

    def test_ddot(self, rng, n, block):
        x, y = _vec(rng, n), _vec(rng, n)
        assert_close(k1.ddot(jnp.asarray(x), jnp.asarray(y), block=block)[0],
                     ref.ddot(x, y), rtol=1e-9)

    def test_dnrm2(self, rng, n, block):
        x = _vec(rng, n)
        assert_close(k1.dnrm2(jnp.asarray(x), block=block)[0],
                     ref.dnrm2_unscaled(x))

    def test_dasum(self, rng, n, block):
        x = _vec(rng, n)
        assert_close(k1.dasum(jnp.asarray(x), block=block)[0], ref.dasum(x))

    def test_drot(self, rng, n, block):
        x, y = _vec(rng, n), _vec(rng, n)
        c, s = jnp.asarray(0.8), jnp.asarray(0.6)
        ox, oy = k1.drot(jnp.asarray(x), jnp.asarray(y), c, s, block=block)
        ex, ey = ref.drot(x, y, c, s)
        assert_close(ox, ex)
        assert_close(oy, ey)


def test_block_must_divide(rng):
    with pytest.raises(ValueError):
        k1.dscal(jnp.asarray(1.0), jnp.asarray(_vec(rng, 100)), block=64)


class TestDmrNoInjection:
    """DMR kernels must be bit-identical to the oracle with no fault armed."""

    def test_dscal_dmr(self, rng):
        x = _vec(rng, 1024)
        alpha = jnp.asarray(3.25)
        out, err = k1d.dscal_dmr(alpha, jnp.asarray(x), NOINJ, block=128)
        assert float(err[0]) == 0.0
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.dscal(alpha, x)))

    def test_daxpy_dmr(self, rng):
        x, y = _vec(rng, 1024), _vec(rng, 1024)
        alpha = jnp.asarray(-1.5)
        out, err = k1d.daxpy_dmr(alpha, jnp.asarray(x), jnp.asarray(y), NOINJ, block=128)
        assert float(err[0]) == 0.0
        # XLA may fuse the mul+add into an FMA differently than the oracle
        # graph; results agree to one ulp.
        assert_close(out, ref.daxpy(alpha, x, y), rtol=1e-15, atol=1e-14)

    def test_ddot_dmr(self, rng):
        x, y = _vec(rng, 1024), _vec(rng, 1024)
        out, err = k1d.ddot_dmr(jnp.asarray(x), jnp.asarray(y), NOINJ, block=128)
        assert float(err[0]) == 0.0
        assert_close(out[0], ref.ddot(x, y), rtol=1e-9)

    def test_dnrm2_dmr(self, rng):
        x = _vec(rng, 1024)
        out, err = k1d.dnrm2_dmr(jnp.asarray(x), NOINJ, block=128)
        assert float(err[0]) == 0.0
        assert_close(out[0], ref.dnrm2_unscaled(x))


@settings(deadline=None, max_examples=15)
@given(
    idx=st.integers(min_value=0, max_value=1023),
    delta=st.floats(min_value=1e-6, max_value=1e12,
                    allow_nan=False, allow_infinity=False),
)
def test_dscal_dmr_detects_and_corrects(idx, delta):
    """Property (paper §4.2): any single injected perturbation of the
    primary compute stream is detected (err count == 1) and the stored
    result equals the fault-free result exactly."""
    rng = np.random.default_rng(idx)
    x = rng.standard_normal(1024)
    alpha = jnp.asarray(1.7)
    inject = jnp.asarray([1.0, float(idx), delta])
    out, err = k1d.dscal_dmr(alpha, jnp.asarray(x), inject, block=128)
    assert float(err[0]) == 1.0
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.dscal(alpha, x)))


@settings(deadline=None, max_examples=15)
@given(
    blk=st.integers(min_value=0, max_value=7),
    delta=st.floats(min_value=1e-6, max_value=1e9,
                    allow_nan=False, allow_infinity=False),
)
def test_ddot_dmr_detects_and_corrects(blk, delta):
    rng = np.random.default_rng(blk + 17)
    x, y = rng.standard_normal(1024), rng.standard_normal(1024)
    inject = jnp.asarray([1.0, float(blk), delta])
    out, err = k1d.ddot_dmr(jnp.asarray(x), jnp.asarray(y), inject, block=128)
    assert float(err[0]) == 1.0
    assert_close(out[0], ref.ddot(x, y), rtol=1e-9)


@settings(deadline=None, max_examples=10)
@given(
    blk=st.integers(min_value=0, max_value=7),
    delta=st.floats(min_value=1e-3, max_value=1e9,
                    allow_nan=False, allow_infinity=False),
)
def test_dnrm2_dmr_detects_and_corrects(blk, delta):
    rng = np.random.default_rng(blk)
    x = rng.standard_normal(1024)
    inject = jnp.asarray([1.0, float(blk), delta])
    out, err = k1d.dnrm2_dmr(jnp.asarray(x), inject, block=128)
    assert float(err[0]) == 1.0
    assert_close(out[0], ref.dnrm2_unscaled(x))


@settings(deadline=None, max_examples=10)
@given(
    n_log2=st.integers(min_value=8, max_value=13),
    blk_log2=st.integers(min_value=5, max_value=8),
    alpha=st.floats(min_value=-100, max_value=100,
                    allow_nan=False, allow_infinity=False),
)
def test_dscal_shape_sweep(n_log2, blk_log2, alpha):
    """Hypothesis sweep over sizes/blocks: kernel == oracle everywhere."""
    n, block = 2 ** n_log2, 2 ** blk_log2
    rng = np.random.default_rng(n)
    x = rng.standard_normal(n)
    a = jnp.asarray(alpha)
    out = k1.dscal(a, jnp.asarray(x), block=block)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.dscal(a, x)))


@settings(deadline=None, max_examples=12)
@given(
    n_mult=st.integers(min_value=1, max_value=32),
    blk_log2=st.integers(min_value=5, max_value=8),
    dtype=st.sampled_from([np.float32, np.float64]),
)
def test_daxpy_shape_dtype_sweep(n_mult, blk_log2, dtype):
    """Shapes x dtypes: daxpy kernel == oracle for any block-multiple
    length in both precisions (the kernel is dtype-generic)."""
    block = 2 ** blk_log2
    n = n_mult * block
    rng = np.random.default_rng(n + blk_log2)
    x = rng.standard_normal(n).astype(dtype)
    y = rng.standard_normal(n).astype(dtype)
    alpha = jnp.asarray(dtype(1.375))  # exactly representable
    out = k1.daxpy(alpha, jnp.asarray(x), jnp.asarray(y), block=block)
    assert out.dtype == x.dtype
    want = np.asarray(ref.daxpy(alpha, x, y))
    # XLA may contract mul+add into a fused multiply-add (one rounding)
    # in either precision — allow 1 ulp
    tol = 2e-7 if dtype == np.float32 else 1e-15
    np.testing.assert_allclose(np.asarray(out), want, rtol=tol, atol=tol)


@settings(deadline=None, max_examples=12)
@given(
    n_mult=st.integers(min_value=1, max_value=16),
    blk_log2=st.integers(min_value=5, max_value=8),
    dtype=st.sampled_from([np.float32, np.float64]),
)
def test_ddot_shape_dtype_sweep(n_mult, blk_log2, dtype):
    block = 2 ** blk_log2
    n = n_mult * block
    rng = np.random.default_rng(n * 3 + blk_log2)
    x = rng.standard_normal(n).astype(dtype)
    y = rng.standard_normal(n).astype(dtype)
    out = k1.ddot(jnp.asarray(x), jnp.asarray(y), block=block)
    rtol = 1e-4 if dtype == np.float32 else 1e-9
    np.testing.assert_allclose(float(out[0]), float(ref.ddot(x, y)), rtol=rtol)


@settings(deadline=None, max_examples=15)
@given(
    flag=st.sampled_from([-2.0, -1.0, 0.0, 1.0]),
    h=st.lists(st.floats(min_value=-3, max_value=3,
                         allow_nan=False, allow_infinity=False),
               min_size=4, max_size=4),
    n_mult=st.integers(min_value=1, max_value=8),
)
def test_drotm_flag_sweep(flag, h, n_mult):
    """DROTM kernel == oracle across every flag mode and H matrix."""
    n = 128 * n_mult
    rng = np.random.default_rng(n + int(flag) + 2)
    x = rng.standard_normal(n)
    y = rng.standard_normal(n)
    param = jnp.asarray([flag] + h)
    ox, oy = k1.drotm(jnp.asarray(x), jnp.asarray(y), param, block=128)
    ex, ey = ref.drotm(x, y, param)
    assert_close(ox, ex)
    assert_close(oy, ey)


def test_drotm_identity_flag(rng):
    x, y = _vec(rng, 256), _vec(rng, 256)
    param = jnp.asarray([-2.0, 9.0, 9.0, 9.0, 9.0])  # H entries ignored
    ox, oy = k1.drotm(jnp.asarray(x), jnp.asarray(y), param, block=64)
    np.testing.assert_array_equal(np.asarray(ox), x)
    np.testing.assert_array_equal(np.asarray(oy), y)
